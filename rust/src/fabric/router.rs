//! The fabric router: a client-side shard fan-out implementing
//! [`Submitter`] over a *dynamic* fleet of fabric servers.
//!
//! **Sharding** is FunctionKind-aware consistent hashing: each ring
//! member contributes virtual nodes to a hash ring and a request's kind
//! picks the first live shard at or after its hash. Same-kind requests
//! land on the same shard, so the per-shard coordinator's dynamic
//! batching sees exactly the stream it would see in-process; losing a
//! shard only remaps the kinds it owned (classic consistent-hashing
//! locality). The ring is keyed by *stable shard index*, so placement
//! after a down/revive cycle is bit-identical to never having failed.
//!
//! **Failover** is health-driven: a shard is marked down when its
//! connection drops, when a write fails, when it answers a request
//! with an all-workers-retired capacity error, or when it misses a
//! data-path heartbeat deadline. In-flight requests on a downed shard
//! are re-routed to the next live shard on the ring (at-least-once
//! execution: results are deterministic functions, so replays are
//! safe). During a *total* outage requests are parked for a bounded
//! [`RouterConfig::retry_window`] — shards are often seconds from
//! revival — and only resolve to an explicit error once the window
//! expires. Clients never hang, mirroring the in-process coordinator's
//! contract.
//!
//! **Heartbeats** (wire v3) close the half-open failure mode: a peer
//! whose TCP connection still accepts writes but never replies (wedged
//! process, blackholed return path) produces no reader EOF and no
//! write error, so without them its in-flight requests would hang
//! forever. The supervisor sends `Ping{nonce}` on each idle-too-long
//! data connection and enforces [`RouterConfig::heartbeat_timeout`];
//! *any* inbound frame — a `Result` ahead of the `Pong` included —
//! proves liveness and clears the outstanding ping, so a busy shard
//! streaming results is never falsely condemned. A missed deadline
//! marks the shard down exactly like a disconnect: the socket is shut
//! down, the reader drains the pending table, and every in-flight
//! request is replayed on the next live shard.
//!
//! **Revival** (§Health, one layer up): membership is not a one-shot
//! property. A supervisor thread periodically re-probes downed shards
//! ([`probe_health`] over short-lived control connections), reopens the
//! data connection, respawns the reader, and atomically returns the
//! shard to ring routing — the fleet-level analogue of the per-crossbar
//! scrub -> remap -> activate-spare loop.
//!
//! **Discovery** is registration-based when [`RouterConfig::listen`] is
//! set: `fabric-serve` processes announce themselves with a `Register`
//! frame (stable `name`, current endpoint, spare flag) instead of a
//! static `--shards` list; a restarted shard re-registering under the
//! same name reclaims its ring slot even at a new port. Registered
//! **hot spares** stay connected but outside the ring until a member is
//! marked down; then they are promoted in (and demoted back once the
//! member revives), mirroring `CoordinatorConfig::spare_workers`.
//!
//! **Metrics** are fetched per shard over short-lived control
//! connections and merged ([`MetricsSnapshot::merge`]) into one fleet
//! view stamped with `shards_total`/`shards_down`, so a degraded fleet
//! is distinguishable from a healthy smaller one.
//!
//! **Authentication** (§Security, wire v4): when [`RouterConfig::psk`]
//! is set, every connection the router makes or accepts — data, control
//! and registration — runs the PSK handshake from [`super::auth`] and
//! is sealed end-to-end. Unauthenticated registrants are rejected
//! before their `Register` frame can touch the ring or the spare pool,
//! tampered or replayed sealed frames fail the MAC and drop the
//! connection (failover then replays in-flight requests exactly like a
//! disconnect), and every rejection is counted in
//! `MetricsSnapshot::auth_rejects` instead of wedging an accept loop.

use std::collections::HashMap;
use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::{
    render_prometheus, MetricsSnapshot, NO_CAPACITY_ERROR, RequestResult, Submitter,
};
use crate::mmpu::FunctionKind;
use crate::telemetry::{
    merge_events, mint_boot_epoch, Event, EventJournal, EventKind, Stage, TraceSpan, Tracer,
    WalConfig, WalFlusher, DEFAULT_JOURNAL_CAPACITY, DEFAULT_SPAN_CAPACITY, SHARD_NONE,
};

use super::auth::{
    client_split, server_split, FrameDecoder, FrameReader, FrameWriter, Psk, Seal, FRAME_DEADLINE,
};
use super::metrics_http::MetricsHttp;
use super::reactor::{self, ConnTx, DataPlane, Epoll, EPOLLIN, EPOLLRDHUP};
use super::wire::Msg;

/// Virtual nodes per shard on the hash ring.
const RING_VNODES: usize = 16;

/// Highest slot index a `Register{prev}` hint may claim. The hint
/// drives slot allocation (placeholders are reserved up to it), so an
/// unbounded value from a corrupt or malicious registrant — the wire
/// runs plaintext unless [`RouterConfig::psk`] is set — could allocate
/// gigabytes under the shards write lock; a stale hint beyond any
/// plausible fleet is ignored and the shard simply gets a fresh slot.
const MAX_PREV_SLOT: usize = 1024;

/// Bound on control-plane connect/read/write, so a hung shard (host
/// down, blackholed traffic) cannot freeze a fleet metrics, health or
/// revival probe. The data path fails over on *closed* connections
/// (reader EOF / write error) and — since wire v3 — on missed
/// data-path heartbeats, which catch the half-open peers no closed
/// connection ever reports (see [`RouterConfig::heartbeat_period`]).
pub(crate) const CONTROL_TIMEOUT: Duration = Duration::from_secs(5);

/// Short-lived control connection with timeouts applied.
pub(crate) fn control_connect(addr: &str) -> Result<TcpStream> {
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr} resolves to no address"))?;
    let stream = TcpStream::connect_timeout(&sock, CONTROL_TIMEOUT)
        .with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_read_timeout(Some(CONTROL_TIMEOUT));
    let _ = stream.set_write_timeout(Some(CONTROL_TIMEOUT));
    Ok(stream)
}

/// Tunables for the router's self-healing membership machinery.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Supervisor tick: how often downed shards are re-probed for
    /// revival, spares reconciled, and parked requests swept.
    pub probe_period: Duration,
    /// How long a request submitted during a total outage may wait for
    /// a revival before resolving to an explicit "no healthy shards"
    /// error (measured from submission; default a few probe periods).
    pub retry_window: Duration,
    /// Bind address of the registration listener (`None`: static
    /// membership only). Shards announce themselves here with
    /// `Register` frames; port 0 binds an ephemeral port (see
    /// [`Router::registration_addr`]).
    pub listen: Option<String>,
    /// How long a live data connection may stay silent (no inbound
    /// frames) before the supervisor sends a `Ping`. Every inbound
    /// frame pushes the next ping out, so under steady traffic no
    /// heartbeat bytes flow at all. `Duration::ZERO` disables
    /// heartbeats entirely (`--hb-ms 0`): `Ping` is a wire-v3 message,
    /// so a pre-v3 shard drops the connection on its first ping —
    /// upgrade shards before routers, or disable heartbeats for the
    /// duration of a mixed-version transition.
    pub heartbeat_period: Duration,
    /// How long after a `Ping` the shard has to produce *any* inbound
    /// frame before it is declared half-open and marked down (its
    /// in-flight requests replay on the next live shard, exactly like a
    /// disconnect). Pings are sent and deadlines checked on supervisor
    /// ticks, so worst-case detection of a peer that goes silent
    /// mid-connection is `heartbeat_period + heartbeat_timeout` plus up
    /// to two `probe_period` ticks (~2.5 s at the defaults); a peer
    /// that is half-open from the moment it connects — the wedged
    /// process the integration suite stubs — is caught within
    /// `heartbeat_timeout` plus two ticks, inside two heartbeat
    /// periods, because the first ping is due immediately on connect.
    pub heartbeat_timeout: Duration,
    /// Fleet PSK (`--psk-file`). `Some` authenticates and seals every
    /// connection this router makes or accepts: shard data connections,
    /// control probes, and the registration listener (an unauthenticated
    /// `Register` never touches the ring or spare pool). `None` keeps
    /// the plaintext v3 behaviour for mixed-version transitions.
    pub psk: Option<Psk>,
    /// §Telemetry (wire v5): sample 1 in `trace_sample` requests for
    /// end-to-end stage tracing. Trace ids are minted here and carried
    /// to the shards, whose coordinators must run the *same* rate for
    /// the fleet to record complementary stages of one timeline
    /// (`fabric-serve --trace-sample`). 0 disables tracing: submits
    /// stay v1-layout frames and the hot path costs one branch.
    pub trace_sample: u64,
    /// §Scale (`--data-plane`): which transport carries the shard data
    /// connections. `Threads` keeps the original blocking
    /// reader-thread-per-shard pairs; `Epoll` multiplexes every shard
    /// connection (reads, heartbeat writes, reply decode) onto one
    /// reactor thread. The control plane (probes, metrics, events,
    /// registration) stays blocking either way. The default follows
    /// the `REMUS_DATA_PLANE` environment variable, so existing
    /// integration/chaos suites re-run under the reactor unchanged.
    pub data_plane: DataPlane,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            probe_period: Duration::from_millis(250),
            retry_window: Duration::from_millis(1000),
            listen: None,
            heartbeat_period: Duration::from_millis(1000),
            heartbeat_timeout: Duration::from_millis(1000),
            psk: None,
            trace_sample: 0,
            data_plane: DataPlane::from_env_or(DataPlane::Threads),
        }
    }
}

/// A request in flight on some shard, retaining everything needed to
/// replay it elsewhere.
struct PendingReq {
    kind: FunctionKind,
    a: u64,
    b: u64,
    reply: Sender<RequestResult>,
    submitted: Instant,
    /// §Telemetry: trace id minted at submit (0 = untraced), carried
    /// on the wire so the shard records complementary stage spans.
    trace: u64,
    /// When the request's frame last hit the socket (== `submitted`
    /// until the first successful write). Splits the router-side time
    /// into queue (submitted -> sent) and wire transit.
    sent: Instant,
    /// Shards already tried (failover never loops within one attempt;
    /// cleared when a parked request is re-dispatched after a
    /// membership change).
    tried: Vec<usize>,
}

/// The write half of a shard data connection, one variant per data
/// plane. Both seal frames in enqueue order, so the implicit seal
/// counters — and therefore the bytes on the wire — are identical
/// across planes.
enum DataTx {
    /// Threads plane: a blocking writer with a bounded write timeout.
    Blocking(FrameWriter),
    /// Epoll plane: a reactor-managed nonblocking transmit queue
    /// (bounded by [`reactor::MAX_CONN_BACKLOG`]; a wedged peer costs
    /// an error here instead of a blocked thread).
    Reactor(ConnTx),
}

impl DataTx {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        match self {
            DataTx::Blocking(w) => w.send(msg),
            DataTx::Reactor(tx) => tx.send(msg),
        }
    }

    /// Shut the underlying socket down in both directions, unblocking
    /// (threads) or waking (epoll) the read side.
    fn shutdown(&self) {
        match self {
            DataTx::Blocking(w) => {
                let _ = w.stream().shutdown(std::net::Shutdown::Both);
            }
            DataTx::Reactor(tx) => tx.shutdown(),
        }
    }
}

/// Per-shard data-path heartbeat state, driven by the supervisor and
/// cleared by the reader (wire v3).
struct HbState {
    /// Nonce of the unanswered `Ping` (0: none outstanding).
    outstanding: u64,
    /// When the outstanding ping expires and the shard is declared
    /// half-open.
    deadline: Instant,
    /// Earliest time the next ping should be sent. Reset by every
    /// inbound frame: a shard streaming results needs no pinging.
    next_ping: Instant,
}

struct ShardState {
    /// Stable identity (the registration key; static shards use their
    /// address). A restarting process re-registers under the same name
    /// to reclaim this slot. Empty on a *placeholder*: a slot reserved
    /// by a `Register{prev}` claim above the current fleet size, held
    /// for the member expected to re-register there (see
    /// [`RouterInner::register`]).
    name: String,
    /// Current endpoint — re-registration after a restart may move it.
    addr: Mutex<String>,
    /// Registered as a hot spare: connected but outside the ring until
    /// promoted to cover a downed member.
    spare: bool,
    /// The role-is-fixed-per-name warning has been emitted for this
    /// slot (the registration refresh loop re-announces twice a second;
    /// one warning is signal, a stream of them is noise).
    role_warned: AtomicBool,
    /// Spare currently promoted into the ring.
    promoted: AtomicBool,
    up: AtomicBool,
    /// The previous connection's reader has fully drained its pending
    /// table — only then may the supervisor open a new connection (no
    /// two readers ever share one pending table).
    reader_gone: AtomicBool,
    /// Write half of the data connection (`None` once down), sealing
    /// frames when the fleet runs authenticated.
    writer: Mutex<Option<DataTx>>,
    /// In-flight requests keyed by wire id.
    pending: Mutex<HashMap<u64, PendingReq>>,
    /// Data-path heartbeat bookkeeping (meaningful only while `up`).
    hb: Mutex<HbState>,
}

impl ShardState {
    fn new(name: String, addr: String, spare: bool) -> Arc<Self> {
        let now = Instant::now();
        Arc::new(Self {
            name,
            addr: Mutex::new(addr),
            spare,
            role_warned: AtomicBool::new(false),
            promoted: AtomicBool::new(false),
            up: AtomicBool::new(false),
            reader_gone: AtomicBool::new(true),
            writer: Mutex::new(None),
            pending: Mutex::new(HashMap::new()),
            hb: Mutex::new(HbState { outstanding: 0, deadline: now, next_ping: now }),
        })
    }

    /// A slot reserved by a `Register{prev}` claim, awaiting the member
    /// expected to re-register at this index (router-restart recovery).
    fn is_placeholder(&self) -> bool {
        self.name.is_empty()
    }

    fn addr(&self) -> String {
        self.addr.lock().unwrap().clone()
    }

    /// In the routing ring right now (members always; spares only while
    /// promoted; placeholders never). A reserved slot contributes its
    /// vnodes only once the real shard claims it — the old router's
    /// ring never contained a slot that was a spare's or that no one
    /// owned, so an unclaimed reservation must not either, or the
    /// rebuilt ring would *not* be bit-identical.
    fn in_ring(&self) -> bool {
        !self.is_placeholder() && (!self.spare || self.promoted.load(Ordering::SeqCst))
    }
}

struct RouterInner {
    cfg: RouterConfig,
    /// Shard slots; grows on registration, never shrinks, so indices —
    /// and therefore ring placement — are stable for the router's
    /// lifetime.
    shards: RwLock<Vec<Arc<ShardState>>>,
    /// Sorted (hash, shard) ring over the current members. Keyed by
    /// shard *index* so the kind->shard map is stable across runs,
    /// ports and down/revive cycles.
    ring: RwLock<Vec<(u64, usize)>>,
    /// Ring-membership epoch: bumped on every down / revive / promote /
    /// demote / (re-)register event, so tests and operators can watch
    /// membership transitions.
    epoch: AtomicU64,
    /// Requests that found no live shard, awaiting a revival or their
    /// retry-window deadline.
    parked: Mutex<Vec<(u64, PendingReq)>>,
    readers: Mutex<Vec<JoinHandle<()>>>,
    /// Epoll plane only: hands freshly authenticated shard connections
    /// to the router reactor thread. `None` on the threads plane (each
    /// connection gets its own blocking reader thread instead).
    reactor_tx: Mutex<Option<Sender<ReactorReg>>>,
    next_id: AtomicU64,
    /// Heartbeat nonce source (starts at 1; 0 means "none outstanding").
    hb_nonce: AtomicU64,
    /// Fleet-wide heartbeat counters, stamped onto the merged snapshot.
    hb_pings: AtomicU64,
    hb_pongs: AtomicU64,
    hb_timeouts: AtomicU64,
    /// Peers this router rejected: failed registration handshakes,
    /// tampered/replayed sealed frames on shard data connections.
    /// Stamped onto the merged snapshot alongside the shards' own
    /// counters.
    auth_rejects: AtomicU64,
    /// §Telemetry: mints trace ids and records the router-side stage
    /// spans (ring queue, wire transit) of sampled requests.
    tracer: Tracer,
    /// §Telemetry: the router's own reliability events (shard down /
    /// revive, heartbeat timeouts, failover replays, spare moves,
    /// auth rejects), recorded with true fleet slot attribution.
    /// Shared (`Arc`) so the `--journal-dir` WAL flusher can drain it.
    journal: Arc<EventJournal>,
    /// Fleet-merged journal state: per-shard pull cursors plus the
    /// merged, causally ordered cache (see [`Router::fleet_events`]).
    fleet: Mutex<FleetEvents>,
    closing: AtomicBool,
}

/// Cursor + cache state behind [`Router::fleet_events`].
#[derive(Default)]
struct FleetEvents {
    /// Next `Events{since}` cursor per shard slot.
    cursors: HashMap<usize, u64>,
    /// Last `boot_epoch` each slot reported (wire v6; absent or 0 for
    /// pre-v6 shards). A *changed* non-zero epoch means the process
    /// behind the slot restarted and its journal sequence numbers
    /// restarted at 0 — the cursor must reset with it, or the new
    /// boot's prefix is silently skipped (the pre-v6 stall bug).
    epochs: HashMap<usize, u64>,
    /// The merged fleet timeline pulled so far (bounded: oldest
    /// entries are dropped past [`FLEET_EVENT_CACHE`]).
    cache: Vec<Event>,
}

/// Upper bound on the router's merged fleet-event cache.
const FLEET_EVENT_CACHE: usize = 8192;

/// A freshly connected (and, with a PSK, freshly authenticated) shard
/// data connection handed from [`connect_shard`] to the router
/// reactor. The stream is already nonblocking; the seals carry the
/// established session's counters.
struct ReactorReg {
    shard_idx: usize,
    stream: TcpStream,
    rx_seal: Option<Seal>,
    tx: ConnTx,
}

/// Router reactor tick: bounds how late a registration, a heartbeat
/// flush, or a frame-deadline expiry can be observed.
const ROUTER_TICK: Duration = Duration::from_millis(10);

/// Observability options for a router (§Observability, wire v6),
/// mirroring [`super::server::ServeOptions`]: the durable flight
/// recorder and the `/metrics` scrape endpoint, both off by default.
#[derive(Default)]
pub struct RouteOptions {
    /// `--journal-dir`: spill the router's own reliability journal
    /// (shard membership, failovers, synthesized restarts) into a
    /// checksummed segment WAL under this directory.
    pub journal_dir: Option<PathBuf>,
    /// `--metrics-addr`: serve the *merged fleet* Prometheus text
    /// exposition over plain HTTP at this address.
    pub metrics_addr: Option<String>,
    /// WAL tuning (segment size, footprint bound, fsync policy).
    pub wal: WalConfig,
}

/// The sharded remote submitter.
pub struct Router {
    inner: Arc<RouterInner>,
    supervisor: Option<JoinHandle<()>>,
    reg_handle: Option<JoinHandle<()>>,
    reg_addr: Option<SocketAddr>,
    /// This boot's random non-zero epoch (wire v6): stamped onto the
    /// router's WAL segments and the `/metrics` exposition.
    boot_epoch: u64,
    /// Background journal→WAL flusher (`--journal-dir`).
    wal: Option<WalFlusher>,
    /// The `/metrics` scrape endpoint (`--metrics-addr`).
    metrics_http: Option<MetricsHttp>,
}

impl Router {
    /// Connect to a static list of shard endpoints with default tuning.
    /// Unreachable shards are marked down (the supervisor keeps probing
    /// them); at least one must be reachable.
    pub fn connect(addrs: &[String]) -> Result<Self> {
        Self::with_config(addrs, RouterConfig::default())
    }

    /// Connect with explicit tuning. `addrs` may be empty when
    /// `cfg.listen` is set — the fleet is then discovered entirely
    /// through shard registration.
    pub fn with_config(addrs: &[String], cfg: RouterConfig) -> Result<Self> {
        Self::with_options(addrs, cfg, RouteOptions::default())
    }

    /// [`Router::with_config`] plus the flight-recorder options: the
    /// journal WAL and the `/metrics` endpoint spawn only when their
    /// options are set; the boot epoch is always minted.
    pub fn with_options(addrs: &[String], cfg: RouterConfig, opts: RouteOptions) -> Result<Self> {
        ensure!(
            !addrs.is_empty() || cfg.listen.is_some(),
            "router needs at least one shard address or a registration listener"
        );
        let shards: Vec<Arc<ShardState>> =
            addrs.iter().map(|a| ShardState::new(a.clone(), a.clone(), false)).collect();
        let inner = Arc::new(RouterInner {
            cfg: cfg.clone(),
            shards: RwLock::new(shards),
            ring: RwLock::new(Vec::new()),
            epoch: AtomicU64::new(0),
            parked: Mutex::new(Vec::new()),
            readers: Mutex::new(Vec::new()),
            reactor_tx: Mutex::new(None),
            next_id: AtomicU64::new(1),
            hb_nonce: AtomicU64::new(1),
            hb_pings: AtomicU64::new(0),
            hb_pongs: AtomicU64::new(0),
            hb_timeouts: AtomicU64::new(0),
            auth_rejects: AtomicU64::new(0),
            tracer: Tracer::new(cfg.trace_sample, DEFAULT_SPAN_CAPACITY),
            journal: Arc::new(EventJournal::new(DEFAULT_JOURNAL_CAPACITY)),
            fleet: Mutex::new(FleetEvents::default()),
            closing: AtomicBool::new(false),
        });
        inner.rebuild_ring();
        // Data plane: the reactor thread must exist before the first
        // shard connection is opened (connect_shard hands connections
        // to it). Its handle joins with the reader handles at shutdown.
        if cfg.data_plane == DataPlane::Epoll {
            if reactor::supported() {
                let (reg_tx, reg_rx) = channel();
                *inner.reactor_tx.lock().unwrap() = Some(reg_tx);
                let inner2 = inner.clone();
                inner
                    .readers
                    .lock()
                    .unwrap()
                    .push(std::thread::spawn(move || router_reactor(inner2, reg_rx)));
            } else {
                eprintln!(
                    "router: warning: the epoll data plane is not supported on this \
                     platform; falling back to threads"
                );
            }
        }
        // Flight recorder first: created before any connection or
        // listener, so every later error path drops (and joins) these
        // cleanly, and the WAL captures the fleet's story from frame
        // one.
        let boot_epoch = mint_boot_epoch();
        let wal = match &opts.journal_dir {
            Some(dir) => Some(
                WalFlusher::spawn(Arc::clone(&inner.journal), dir, boot_epoch, opts.wal)
                    .with_context(|| format!("opening journal WAL in {}", dir.display()))?,
            ),
            None => None,
        };
        let metrics_http = match &opts.metrics_addr {
            Some(maddr) => {
                let inner = inner.clone();
                Some(MetricsHttp::serve(maddr, move || {
                    render_prometheus(&inner.merged_metrics(), boot_epoch)
                })?)
            }
            None => None,
        };
        for i in 0..addrs.len() {
            if let Err(e) = connect_shard(&inner, i) {
                eprintln!("router: shard {i} ({}) unreachable at connect: {e:#}", addrs[i]);
            }
        }
        if !addrs.is_empty() {
            ensure!(inner.live_shards() > 0, "no reachable shard among {addrs:?}");
        }
        let (reg_addr, reg_handle) = match &cfg.listen {
            Some(addr) => match spawn_registration_listener(inner.clone(), addr) {
                Ok((bound, handle)) => (Some(bound), Some(handle)),
                Err(e) => {
                    // Unwind the connections already opened so their
                    // reader threads exit instead of leaking.
                    inner.closing.store(true, Ordering::SeqCst);
                    for i in 0..inner.shards.read().unwrap().len() {
                        inner.mark_down(i);
                    }
                    return Err(e);
                }
            },
            None => (None, None),
        };
        let supervisor = {
            let inner = inner.clone();
            Some(std::thread::spawn(move || supervisor_loop(inner)))
        };
        Ok(Self { inner, supervisor, reg_handle, reg_addr, boot_epoch, wal, metrics_http })
    }

    /// This boot's random non-zero epoch (wire v6).
    pub fn boot_epoch(&self) -> u64 {
        self.boot_epoch
    }

    /// The `/metrics` endpoint address, when one was configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_http.as_ref().map(|m| m.local_addr())
    }

    /// The registration listener's bound address (resolves port 0), or
    /// `None` without one.
    pub fn registration_addr(&self) -> Option<SocketAddr> {
        self.reg_addr
    }

    /// The shard a kind currently routes to (None with every shard
    /// down). Exposed for tests and fleet introspection.
    pub fn shard_for(&self, kind: FunctionKind) -> Option<usize> {
        self.inner.shard_for(kind)
    }

    /// The kind's full ring preference order over the *current*
    /// membership, liveness ignored (placement, not routing). After a
    /// down/revive cycle this must be identical to never having failed.
    pub fn ring_walk(&self, kind: FunctionKind) -> Vec<usize> {
        self.inner.ring_order(hash_kind(kind))
    }

    /// Addresses this router currently knows, in stable shard order.
    pub fn shard_addrs(&self) -> Vec<String> {
        self.inner.shards.read().unwrap().iter().map(|s| s.addr()).collect()
    }

    /// Total shard slots (static + registered, spares included).
    pub fn shard_count(&self) -> usize {
        self.inner.shards.read().unwrap().len()
    }

    /// Shards with a live data connection right now (spares included).
    pub fn live_shards(&self) -> usize {
        self.inner.live_shards()
    }

    /// Current ring-membership epoch (bumps on every down / revive /
    /// promote / demote / register event).
    pub fn membership_epoch(&self) -> u64 {
        self.inner.epoch.load(Ordering::SeqCst)
    }

    /// CLI bootstrap shared by `remus serve`/`fabric-route` and the
    /// serve example: with a registration listener configured, print
    /// its address (for `fabric-serve --register`) and wait for
    /// `min_live` shards before the caller drives load, warning (not
    /// failing) on timeout. No-op without a listener.
    pub fn announce_and_wait(&self, min_live: usize, timeout: Duration, ctx: &str) {
        let Some(reg) = self.registration_addr() else { return };
        println!("REGISTRATION {reg}");
        if !self.wait_for_live(min_live, timeout) {
            eprintln!(
                "{ctx}: only {}/{min_live} shards live after {timeout:?}; continuing",
                self.live_shards()
            );
        }
    }

    /// Block until at least `n` shards are live, or `timeout` expires.
    /// Returns whether the target was reached (used by `fabric-route
    /// --listen-reg` before driving load, and by tests).
    pub fn wait_for_live(&self, n: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while self.live_shards() < n {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        true
    }

    pub fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        let (tx, rx) = channel();
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let trace = self.inner.tracer.mint();
        let now = Instant::now();
        self.inner.route(
            id,
            PendingReq {
                kind,
                a,
                b,
                reply: tx,
                submitted: now,
                trace,
                sent: now,
                tried: Vec::new(),
            },
        );
        rx
    }

    /// §Telemetry: the router-side tracer (router queue and wire
    /// transit spans of sampled requests; see `remus trace`).
    pub fn tracer(&self) -> &Tracer {
        &self.inner.tracer
    }

    /// §Telemetry: the router's own reliability event journal (shard
    /// membership, heartbeat timeouts, failover replays, auth rejects).
    pub fn journal(&self) -> &EventJournal {
        &self.inner.journal
    }

    /// Router-side stage spans recorded so far.
    pub fn spans(&self) -> Vec<TraceSpan> {
        self.inner.tracer.spans()
    }

    /// Fleet-wide stage spans: the router's own plus every reachable
    /// shard's, pulled over short-lived control connections
    /// (`SpansReq`, wire v5). Unreachable shards are skipped — a trace
    /// is best-effort observability, never a liveness dependency.
    pub fn fleet_spans(&self) -> Vec<TraceSpan> {
        let shards: Vec<Arc<ShardState>> = self
            .inner
            .shards
            .read()
            .unwrap()
            .iter()
            .filter(|s| !s.is_placeholder())
            .cloned()
            .collect();
        let probes: Vec<_> = shards
            .iter()
            .map(|shard| {
                let addr = shard.addr();
                let psk = self.inner.cfg.psk.clone();
                std::thread::spawn(move || fetch_spans_auth(&addr, psk.as_ref()))
            })
            .collect();
        let mut spans = self.inner.tracer.spans();
        for probe in probes {
            if let Ok(Ok(mut s)) = probe.join() {
                spans.append(&mut s);
            }
        }
        spans
    }

    /// The merged fleet-wide reliability journal: the router's own
    /// events plus every reachable shard's, pulled incrementally with
    /// per-shard `Events{since}` cursors and merged into one causally
    /// ordered timeline (wall-clock order with a total tiebreak — see
    /// [`merge_events`]). Imported events are re-stamped with the
    /// shard's fleet slot so `shard` attribution is fleet-truthful
    /// (shard-local journals record themselves as shard 0).
    /// Unreachable shards are skipped this pull; their cursor is
    /// untouched, so nothing is lost — only delayed.
    ///
    /// **Restart detection** (wire v6): every reply carries the
    /// shard's `boot_epoch`. When a slot's epoch *changes*, the
    /// process behind it restarted and its journal sequence numbers
    /// restarted at 0 — the stale cursor would silently skip the new
    /// boot's entire prefix (`since` self-heals the cursor *value*,
    /// but loses the events). The router resets the cursor, re-pulls
    /// that shard from 0, and synthesizes a
    /// [`EventKind::ShardRestarted`] marker into its own journal so
    /// the merged timeline shows the discontinuity.
    pub fn fleet_events(&self) -> Vec<Event> {
        let shards: Vec<(usize, Arc<ShardState>)> = self
            .inner
            .shards
            .read()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.is_placeholder())
            .map(|(i, s)| (i, s.clone()))
            .collect();
        let (cursors, known_epochs): (Vec<u64>, Vec<u64>) = {
            let fleet = self.inner.fleet.lock().unwrap();
            shards
                .iter()
                .map(|(i, _)| {
                    (
                        fleet.cursors.get(i).copied().unwrap_or(0),
                        fleet.epochs.get(i).copied().unwrap_or(0),
                    )
                })
                .unzip()
        };
        let probes: Vec<_> = shards
            .iter()
            .zip(cursors.iter().zip(&known_epochs))
            .map(|((slot, shard), (&since, &known))| {
                let slot = *slot;
                let addr = shard.addr();
                let psk = self.inner.cfg.psk.clone();
                std::thread::spawn(move || {
                    let mut fetched = fetch_events_auth(&addr, psk.as_ref(), since);
                    let mut restarted = false;
                    if let Ok((_, _, epoch)) = &fetched {
                        if *epoch != 0 && known != 0 && *epoch != known {
                            // Epoch changed mid-stream: the first pull
                            // used a cursor from the previous boot and
                            // missed the new journal's prefix. Re-pull
                            // from 0 — one extra round-trip, only on a
                            // restart.
                            restarted = true;
                            fetched = fetch_events_auth(&addr, psk.as_ref(), 0);
                        }
                    }
                    (slot, restarted, fetched)
                })
            })
            .collect();
        let mut fresh: Vec<Event> = Vec::new();
        let mut advanced: Vec<(usize, u64, u64)> = Vec::new();
        for probe in probes {
            let Ok((slot, restarted, fetched)) = probe.join() else { continue };
            match fetched {
                Ok((events, latest, epoch)) => {
                    if restarted {
                        self.inner.journal.record_for(
                            slot as u32,
                            EventKind::ShardRestarted { shard: slot as u32, epoch },
                        );
                        eprintln!(
                            "router: shard {slot} journal restarted (boot epoch {epoch:#x}); \
                             cursor reset"
                        );
                    }
                    for mut e in events {
                        // Shard-local journals self-identify as shard 0
                        // (a shard does not know its fleet slot); the
                        // router is the one place that does.
                        e.shard = slot as u32;
                        fresh.push(e);
                    }
                    advanced.push((slot, latest, epoch));
                }
                Err(e) => {
                    if !self.inner.closing.load(Ordering::SeqCst) {
                        eprintln!("router: events from shard {slot} unavailable: {e:#}");
                    }
                }
            }
        }
        fresh.extend(self.inner.journal.events());
        let mut fleet = self.inner.fleet.lock().unwrap();
        for (slot, latest, epoch) in advanced {
            fleet.cursors.insert(slot, latest);
            if epoch != 0 {
                fleet.epochs.insert(slot, epoch);
            }
        }
        let cache = std::mem::take(&mut fleet.cache);
        let mut merged = merge_events(cache, fresh);
        if merged.len() > FLEET_EVENT_CACHE {
            merged.drain(..merged.len() - FLEET_EVENT_CACHE);
        }
        fleet.cache = merged.clone();
        merged
    }

    /// The last `boot_epoch` observed per fleet slot (wire v6; slots
    /// that never reported one are absent). `remus top` diffs this
    /// between pulls to flag restarted shards.
    pub fn fleet_epochs(&self) -> HashMap<usize, u64> {
        self.inner.fleet.lock().unwrap().epochs.clone()
    }

    /// Merged fleet metrics: every shard (even one marked down for
    /// routing — its server may still answer control traffic) is probed
    /// over a short-lived connection; unreachable shards are skipped
    /// but still counted in `shards_total`/`shards_down`, so a degraded
    /// fleet never masquerades as a healthy smaller one. Probes run
    /// concurrently, so a fleet of dead shards costs one
    /// `CONTROL_TIMEOUT`, not a serial sum; the merge keeps shard order.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.inner.merged_metrics()
    }

    pub fn is_serving(&self) -> bool {
        self.live_shards() > 0
    }

    /// Close every shard connection, stop the supervisor and
    /// registration listener, and join the reader threads. In-flight
    /// and parked requests resolve with explicit shutdown errors.
    pub fn shutdown(mut self) {
        self.inner.closing.store(true, Ordering::SeqCst);
        let n = self.inner.shards.read().unwrap().len();
        for i in 0..n {
            self.inner.mark_down(i);
        }
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        if let Some(h) = self.reg_handle.take() {
            let _ = h.join();
        }
        // The supervisor may have completed a revival racing the close
        // above; with it joined, one more pass closes any connection it
        // opened so no reader blocks the joins below.
        for i in 0..self.inner.shards.read().unwrap().len() {
            self.inner.mark_down(i);
        }
        let readers: Vec<_> = self.inner.readers.lock().unwrap().drain(..).collect();
        for h in readers {
            let _ = h.join();
        }
        let parked: Vec<_> = self.inner.parked.lock().unwrap().drain(..).collect();
        for (_, req) in parked {
            let latency = req.submitted.elapsed();
            let _ = req.reply.send(RequestResult {
                value: 0,
                latency,
                error: Some("router shutting down".to_string()),
            });
        }
        // Last: the WAL flusher's stop path performs a final journal
        // drain, so the shutdown-time membership events above are on
        // disk before the process exits.
        if let Some(wal) = self.wal.take() {
            wal.stop();
        }
        if let Some(m) = self.metrics_http.take() {
            m.shutdown();
        }
    }
}

impl Submitter for Router {
    fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        Router::submit(self, kind, a, b)
    }

    fn metrics(&self) -> MetricsSnapshot {
        Router::metrics(self)
    }

    fn is_serving(&self) -> bool {
        Router::is_serving(self)
    }
}

impl RouterInner {
    fn shard(&self, i: usize) -> Option<Arc<ShardState>> {
        self.shards.read().unwrap().get(i).cloned()
    }

    /// Merged fleet metrics (the body behind [`Router::metrics`] —
    /// also rendered by the `/metrics` endpoint, which holds only an
    /// `Arc<RouterInner>`). Placeholder slots (reserved by a
    /// `Register{prev}` claim, never yet claimed) have no endpoint:
    /// they are skipped and excluded from the membership counters, so
    /// a stale reservation cannot make a healthy fleet report down
    /// shards.
    fn merged_metrics(&self) -> MetricsSnapshot {
        let shards: Vec<Arc<ShardState>> =
            self.shards.read().unwrap().iter().filter(|s| !s.is_placeholder()).cloned().collect();
        let probes: Vec<_> = shards
            .iter()
            .map(|shard| {
                let addr = shard.addr();
                let psk = self.cfg.psk.clone();
                std::thread::spawn(move || {
                    let m = fetch_metrics_auth(&addr, psk.as_ref());
                    (addr, m)
                })
            })
            .collect();
        let mut merged = MetricsSnapshot::default();
        for probe in probes {
            match probe.join() {
                Ok((_, Ok(m))) => merged.merge(&m),
                Ok((addr, Err(e))) => {
                    eprintln!("router: metrics from {addr} unavailable: {e:#}")
                }
                Err(_) => {}
            }
        }
        merged.shards_total = shards.len() as u64;
        merged.shards_down = shards.iter().filter(|s| !s.up.load(Ordering::SeqCst)).count() as u64;
        // Heartbeat traffic is a router-side property (per-shard
        // snapshots carry zeros), so stamping — like the membership
        // counters above — composes under nested merges.
        merged.hb_pings += self.hb_pings.load(Ordering::Relaxed);
        merged.hb_pongs += self.hb_pongs.load(Ordering::Relaxed);
        merged.hb_timeouts += self.hb_timeouts.load(Ordering::Relaxed);
        // Auth rejects *add*: the shards count the peers they turned
        // away, the router adds its own (registration handshakes,
        // tampered data frames).
        merged.auth_rejects += self.auth_rejects.load(Ordering::Relaxed);
        merged
    }

    fn live_shards(&self) -> usize {
        self.shards.read().unwrap().iter().filter(|s| s.up.load(Ordering::SeqCst)).count()
    }

    fn bump_epoch(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
    }

    /// Regenerate the ring from current membership (members + promoted
    /// spares). Vnode hashes depend only on the stable shard index, so
    /// regenerating after a revive/demote cycle reproduces the original
    /// ring bit for bit.
    fn rebuild_ring(&self) {
        let shards = self.shards.read().unwrap();
        let mut ring = Vec::with_capacity(shards.len() * RING_VNODES);
        for (i, s) in shards.iter().enumerate() {
            if !s.in_ring() {
                continue;
            }
            for vnode in 0..RING_VNODES {
                ring.push((fnv64(format!("shard{i}/vnode{vnode}").as_bytes()), i));
            }
        }
        drop(shards);
        ring.sort_unstable();
        *self.ring.write().unwrap() = ring;
    }

    /// Walk shard indices in ring order starting at `hash` (vnodes
    /// deduplicated), yielding each ring member once.
    fn ring_order(&self, hash: u64) -> Vec<usize> {
        let ring = self.ring.read().unwrap();
        if ring.is_empty() {
            return Vec::new();
        }
        let start = ring.partition_point(|&(h, _)| h < hash);
        // O(1) dedup bitmap sized from the ring itself (every routing
        // decision walks this; a linear `contains` would make it
        // quadratic in fleet size).
        let max_idx = ring.iter().map(|&(_, s)| s).max().unwrap_or(0);
        let mut seen = vec![false; max_idx + 1];
        let mut order = Vec::new();
        for k in 0..ring.len() {
            let shard = ring[(start + k) % ring.len()].1;
            if !seen[shard] {
                seen[shard] = true;
                order.push(shard);
            }
        }
        order
    }

    fn shard_for(&self, kind: FunctionKind) -> Option<usize> {
        let shards = self.shards.read().unwrap();
        self.ring_order(hash_kind(kind))
            .into_iter()
            .find(|&s| shards.get(s).is_some_and(|sh| sh.up.load(Ordering::SeqCst)))
    }

    /// Dispatch (or re-dispatch) a request to the first live shard on
    /// its kind's ring walk that hasn't been tried yet. With none left:
    /// park it for the retry window (a revival may be seconds away), or
    /// resolve it with an explicit error once the window has expired.
    fn route(&self, id: u64, mut req: PendingReq) {
        for shard_idx in self.ring_order(hash_kind(req.kind)) {
            if req.tried.contains(&shard_idx) {
                continue;
            }
            let Some(shard) = self.shard(shard_idx) else { continue };
            if !shard.up.load(Ordering::SeqCst) {
                continue;
            }
            req.tried.push(shard_idx);
            let msg = Msg::Submit { id, kind: req.kind, a: req.a, b: req.b, trace: req.trace };
            // Stamp the queue->wire boundary now (the write happens a
            // lock acquisition later): submitted -> sent is the
            // RouterQueue span of a sampled request.
            req.sent = Instant::now();
            // Register before writing so the reader can match a fast
            // reply; reclaim on write failure.
            shard.pending.lock().unwrap().insert(id, req);
            let wrote = match shard.writer.lock().unwrap().as_mut() {
                Some(writer) => writer.send(&msg).is_ok(),
                None => false,
            };
            if wrote {
                return;
            }
            self.mark_down(shard_idx);
            req = match shard.pending.lock().unwrap().remove(&id) {
                Some(r) => r,
                // The reader drained it first and is re-routing it.
                None => return,
            };
        }
        // Total outage on this walk: hold the request for the bounded
        // retry window instead of failing instantly — the supervisor
        // re-dispatches it on the next membership change and expires it
        // at the deadline.
        if !self.closing.load(Ordering::SeqCst) && req.submitted.elapsed() < self.cfg.retry_window
        {
            self.parked.lock().unwrap().push((id, req));
            return;
        }
        let latency = req.submitted.elapsed();
        let _ = req.reply.send(RequestResult {
            value: 0,
            latency,
            error: Some(format!("no healthy shards (tried {:?})", req.tried)),
        });
    }

    /// Take a shard out of routing, unblock its reader, and promote a
    /// spare to cover it.
    fn mark_down(&self, i: usize) {
        let Some(shard) = self.shard(i) else { return };
        let was_up = shard.up.swap(false, Ordering::SeqCst);
        if let Some(w) = shard.writer.lock().unwrap().take() {
            w.shutdown();
        }
        if was_up {
            self.bump_epoch();
            if !self.closing.load(Ordering::SeqCst) {
                eprintln!("router: shard {i} ({}) marked down", shard.addr());
                self.journal.record_for(i as u32, EventKind::ShardDown { shard: i as u32 });
                self.reconcile_spares();
            }
        }
    }

    /// Promote exactly as many (live) spares into the ring as there are
    /// downed members; demote the rest. Idempotent and deterministic
    /// (stable index order), called on every membership event — so a
    /// revival automatically demotes the spare that covered it.
    fn reconcile_spares(&self) {
        if self.closing.load(Ordering::SeqCst) {
            return;
        }
        let shards = self.shards.read().unwrap();
        // Placeholders are not failed members: a spare must cover a
        // member that *was* serving and went down, not a slot reserved
        // for a re-registration that may never come (a stale prev
        // hint would otherwise pin spares into the ring forever).
        let mut need = shards
            .iter()
            .filter(|s| !s.spare && !s.is_placeholder() && !s.up.load(Ordering::SeqCst))
            .count();
        let mut changed = false;
        for (i, s) in shards.iter().enumerate() {
            if !s.spare {
                continue;
            }
            let want = need > 0 && s.up.load(Ordering::SeqCst);
            if want {
                need -= 1;
            }
            if s.promoted.swap(want, Ordering::SeqCst) != want {
                changed = true;
                eprintln!(
                    "router: spare shard {i} ({}) {}",
                    s.addr(),
                    if want { "promoted into the ring" } else { "demoted back to the pool" }
                );
                let kind = if want {
                    EventKind::SparePromote { unit: i as u32 }
                } else {
                    EventKind::SpareDemote { unit: i as u32 }
                };
                self.journal.record_for(i as u32, kind);
            }
        }
        drop(shards);
        if changed {
            self.rebuild_ring();
            self.bump_epoch();
        }
    }

    /// Add (or refresh) a shard from a `Register` frame. Returns the
    /// stable index and whether the shard is immediately in the ring.
    ///
    /// Re-registration under a known name is idempotent — shards
    /// re-announce themselves every [`super::server::REG_REFRESH`], so
    /// a restarted *router* rediscovers its whole fleet; an unchanged
    /// endpoint is a silent refresh, a changed one is adopted and
    /// logged. An unknown name carrying `prev` (the slot index a
    /// previous router's `Welcome` assigned) reclaims that exact index,
    /// reserving placeholder slots below it if its peers have not
    /// re-registered yet — so the rebuilt ring is bit-identical to the
    /// old router's regardless of re-registration order. A placeholder
    /// that is never claimed (a stale hint from an older, larger
    /// fleet) stays *inert*: it is skipped by revival probing, spare
    /// reconciliation and the fleet membership counters, and remains
    /// claimable by a late re-registration.
    fn register(
        &self,
        name: String,
        addr: String,
        spare: bool,
        prev: Option<u32>,
    ) -> (usize, bool) {
        let mut shards = self.shards.write().unwrap();
        // Placeholders are excluded from the name match: their name is
        // the empty string, and an empty-name registrant (already
        // rejected at the listener) must never hijack a slot reserved
        // for a re-registering member.
        if let Some((i, s)) =
            shards.iter().enumerate().find(|(_, s)| !s.is_placeholder() && s.name == name)
        {
            // Known name: the shard restarted (possibly on a new port)
            // and reclaims its slot, or this is a periodic refresh. The
            // member/spare role is fixed for the slot's lifetime — the
            // Welcome ack reports the slot's actual state, and a
            // flipped role flag is warned about once per slot (on the
            // silent same-address refresh path too, so pinned-address
            // deployments see it).
            let active = s.in_ring();
            if s.spare != spare && !s.role_warned.swap(true, Ordering::SeqCst) {
                eprintln!(
                    "router: shard {i} ({name}) re-registered asking to be a {}, but its \
                     slot is a {}; role is fixed per name",
                    if spare { "spare" } else { "member" },
                    if s.spare { "spare" } else { "member" }
                );
            }
            let mut a = s.addr.lock().unwrap();
            if *a == addr {
                return (i, active);
            }
            *a = addr.clone();
            drop(a);
            drop(shards);
            self.bump_epoch();
            eprintln!("router: shard {i} ({name}) re-registered at {addr}");
            return (i, active);
        }
        if let Some(p) = prev.map(|p| p as usize).filter(|&p| p <= MAX_PREV_SLOT) {
            // Router-restart recovery: the shard remembers the slot a
            // previous router assigned it. Reserve the run of slots up
            // to it (peers will claim theirs momentarily) and take the
            // exact index — unless a different live name got there
            // first, in which case the hint is stale and the shard
            // falls through to a fresh slot. Hints beyond
            // [`MAX_PREV_SLOT`] are ignored outright (see the const).
            while shards.len() <= p {
                shards.push(ShardState::new(String::new(), String::new(), false));
            }
            if shards[p].is_placeholder() {
                shards[p] = ShardState::new(name.clone(), addr.clone(), spare);
                let active = shards[p].in_ring();
                drop(shards);
                self.rebuild_ring();
                self.bump_epoch();
                eprintln!(
                    "router: shard {p} ({name}) reclaimed its previous slot at {addr}{}",
                    if spare { " as a hot spare" } else { "" }
                );
                return (p, active);
            }
        }
        let idx = shards.len();
        shards.push(ShardState::new(name.clone(), addr.clone(), spare));
        drop(shards);
        if !spare {
            self.rebuild_ring();
        }
        self.bump_epoch();
        eprintln!(
            "router: shard {idx} ({name}) registered at {addr}{}",
            if spare { " as a hot spare" } else { "" }
        );
        (idx, !spare)
    }
}

/// Open shard `i`'s data connection, store the write half, hand the
/// read half to a reader (a dedicated thread on the threads plane, the
/// shared reactor on the epoll plane), and atomically return the shard
/// to routing.
fn connect_shard(inner: &Arc<RouterInner>, i: usize) -> Result<()> {
    ensure!(!inner.closing.load(Ordering::SeqCst), "router shutting down");
    let shard = inner.shard(i).ok_or_else(|| anyhow!("no shard {i}"))?;
    ensure!(
        shard.reader_gone.load(Ordering::SeqCst),
        "shard {i} still has a reader draining its previous connection"
    );
    let addr = shard.addr();
    let stream =
        TcpStream::connect(addr.as_str()).with_context(|| format!("connecting to shard {addr}"))?;
    let _ = stream.set_nodelay(true);
    // Authenticate first (when the fleet runs with a PSK): a shard that
    // cannot complete the handshake never gets a writer, a reader, or a
    // ring slot back. The handshake itself is blocking on both planes —
    // its bytes must be identical — and bounded by its own timeouts.
    let (reader, writer) = client_split(stream, inner.cfg.psk.as_ref(), None)
        .with_context(|| format!("authenticating to shard {addr}"))?;
    let reg_tx = inner.reactor_tx.lock().unwrap().clone();
    match reg_tx {
        None => {
            // Threads plane. Bound data-path writes: a peer wedged with
            // full TCP buffers must surface as a write error (->
            // failover) rather than blocking the submitting thread or
            // the heartbeat sweep. Capped at the heartbeat timeout
            // (floored for very aggressive test configs) so a blocked
            // write never stalls the supervisor longer than the
            // detection deadline it is enforcing. Set *after* the
            // handshake (which uses its own short bound). Idle reads
            // stay unbounded — the reader is *designed* to block
            // between frames, and half-open silence is the heartbeat
            // deadline's job; only a frame started and never finished
            // trips the reader's deadline.
            let write_timeout = inner.cfg.heartbeat_timeout.max(Duration::from_millis(100));
            let _ = writer.stream().set_write_timeout(Some(write_timeout));
            *shard.writer.lock().unwrap() = Some(DataTx::Blocking(writer));
            // Fresh heartbeat slate, with the first ping due
            // immediately: a half-open peer (or one that wedged while
            // down) is condemned within one heartbeat timeout of
            // connecting, before it can absorb much traffic.
            {
                let now = Instant::now();
                *shard.hb.lock().unwrap() =
                    HbState { outstanding: 0, deadline: now, next_ping: now };
            }
            shard.reader_gone.store(false, Ordering::SeqCst);
            shard.up.store(true, Ordering::SeqCst);
            inner.bump_epoch();
            let inner2 = inner.clone();
            let handle = std::thread::spawn(move || reader_loop(inner2, i, reader));
            let mut readers = inner.readers.lock().unwrap();
            // Reap finished readers so a long-lived router reviving
            // shards many times does not accumulate a handle per
            // connection.
            readers.retain(|h| !h.is_finished());
            readers.push(handle);
        }
        Some(reg_tx) => {
            // Epoll plane: take the blocking halves apart (preserving
            // the seals' frame counters) and go nonblocking. O_NONBLOCK
            // lives on the shared open file description, so one call
            // covers both dup'd halves; write timeouts are moot — a
            // full socket buffer queues into the ConnTx backlog instead
            // of blocking, bounded by `reactor::MAX_CONN_BACKLOG`.
            let (read_stream, rx_seal) = reader.into_parts();
            let (write_stream, tx_seal) = writer.into_parts();
            read_stream
                .set_nonblocking(true)
                .with_context(|| format!("nonblocking mode for shard {addr}"))?;
            let tx = ConnTx::new(write_stream, tx_seal);
            {
                let now = Instant::now();
                *shard.hb.lock().unwrap() =
                    HbState { outstanding: 0, deadline: now, next_ping: now };
            }
            shard.reader_gone.store(false, Ordering::SeqCst);
            *shard.writer.lock().unwrap() = Some(DataTx::Reactor(tx.clone()));
            let reg = ReactorReg { shard_idx: i, stream: read_stream, rx_seal, tx };
            if reg_tx.send(reg).is_err() {
                // Reactor gone (failed at startup, or shutdown raced
                // this connect): undo and fail the connect loudly.
                if let Some(w) = shard.writer.lock().unwrap().take() {
                    w.shutdown();
                }
                shard.reader_gone.store(true, Ordering::SeqCst);
                bail!("router reactor is not running");
            }
            shard.up.store(true, Ordering::SeqCst);
            inner.bump_epoch();
        }
    }
    Ok(())
}

/// Per-shard reader (threads plane): matches `Result` frames to pending
/// requests, turns capacity errors into failovers, and on disconnect
/// re-routes whatever was still in flight, then hands the slot back for
/// revival. The message handling and the exit drain are shared with the
/// epoll plane ([`handle_shard_msg`], [`shard_conn_closed`]), so both
/// planes fail over identically by construction.
fn reader_loop(inner: Arc<RouterInner>, shard_idx: usize, mut reader: FrameReader) {
    let Some(shard) = inner.shard(shard_idx) else { return };
    loop {
        let msg = match reader.recv() {
            Ok(Some(m)) => m,
            Ok(None) => break,
            Err(e) => {
                // On a sealed connection a recv error past the clean-EOF
                // path is a tampered, replayed or reordered frame: count
                // it, then fail over exactly like a disconnect — the
                // drain below replays every in-flight request on the
                // next live shard, so the attack costs zero replies.
                if reader.is_sealed() {
                    shard_integrity_reject(&inner, shard_idx, &e);
                }
                break;
            }
        };
        if !handle_shard_msg(&inner, &shard, shard_idx, msg) {
            break;
        }
    }
    shard_conn_closed(&inner, shard_idx, &shard);
}

/// Count (and journal) a tampered/replayed/trickled frame on a sealed
/// shard data connection — shared by both planes' read paths.
fn shard_integrity_reject(inner: &RouterInner, shard_idx: usize, e: &anyhow::Error) {
    if inner.closing.load(Ordering::SeqCst) {
        return;
    }
    inner.auth_rejects.fetch_add(1, Ordering::SeqCst);
    inner.journal.record_for(SHARD_NONE, EventKind::AuthReject);
    eprintln!("router: shard {shard_idx} data connection failed integrity: {e:#}");
}

/// Handle one inbound frame on a shard data connection. Returns `false`
/// on a protocol violation (the connection must be dropped). This is
/// the single message path for both data planes: the threads reader and
/// the epoll reactor produce bit-identical routing, failover, heartbeat
/// and tracing behaviour because they run exactly this code.
fn handle_shard_msg(
    inner: &RouterInner,
    shard: &ShardState,
    shard_idx: usize,
    msg: Msg,
) -> bool {
    // Any inbound frame proves the data path is alive in both
    // directions: clear the outstanding ping (a Result racing ahead
    // of its Pong counts) and push the next one out.
    {
        let mut hb = shard.hb.lock().unwrap();
        hb.outstanding = 0;
        hb.next_ping = Instant::now() + inner.cfg.heartbeat_period;
    }
    match msg {
        Msg::Result { id, value, latency_us, error } => {
            let req = shard.pending.lock().unwrap().remove(&id);
            let Some(req) = req else { return true };
            // An all-workers-retired shard answers every request
            // with the coordinator's capacity error: mark it down
            // and fail the request over instead of delivering it.
            let capacity_error = error.as_deref().is_some_and(|e| e.contains(NO_CAPACITY_ERROR));
            if capacity_error && !inner.closing.load(Ordering::SeqCst) {
                inner.mark_down(shard_idx);
                inner.route(id, req);
                return true;
            }
            let latency = req.submitted.elapsed();
            if inner.tracer.sampled(req.trace) {
                // Router-side stages of a sampled request: queue
                // (submitted -> last socket write) and wire transit
                // (everything the shard's own spans don't cover).
                // The shard reported its service time truncated to
                // whole µs; rounding it *up* here keeps the
                // fleet-wide invariant sum(stages) <= e2e.
                let e2e = latency.as_nanos() as u64;
                let queue = req.sent.saturating_duration_since(req.submitted).as_nanos() as u64;
                let service = (latency_us + 1) * 1000;
                let transit = e2e.saturating_sub(queue).saturating_sub(service);
                let t0 = inner.tracer.ns_of(req.submitted);
                inner.tracer.record(req.trace, Stage::RouterQueue, t0, queue);
                inner.tracer.record(req.trace, Stage::WireTransit, t0 + queue, transit);
            }
            let _ = req.reply.send(RequestResult { value, latency, error });
            true
        }
        Msg::Pong { nonce: _ } => {
            inner.hb_pongs.fetch_add(1, Ordering::Relaxed);
            true
        }
        // Control replies ride dedicated connections; anything else
        // here is a protocol violation — drop the connection.
        _ => false,
    }
}

/// The shared reader exit path: mark the shard down, fail over (or, at
/// router shutdown, resolve) the in-flight tail, and only then hand the
/// slot back for revival. On the threads plane this runs as the reader
/// thread's tail; on the epoll plane the reactor runs it when it
/// retires a connection — either way the pending table is empty before
/// `reader_gone` flips, so no two readers ever share one table.
fn shard_conn_closed(inner: &RouterInner, shard_idx: usize, shard: &ShardState) {
    inner.mark_down(shard_idx);
    let drained: Vec<(u64, PendingReq)> = shard.pending.lock().unwrap().drain().collect();
    let closing = inner.closing.load(Ordering::SeqCst);
    if !drained.is_empty() && !closing {
        eprintln!(
            "router: shard {shard_idx} disconnected with {} in flight; rerouting",
            drained.len()
        );
        inner.journal.record_for(
            shard_idx as u32,
            EventKind::FailoverReplay { shard: shard_idx as u32, replayed: drained.len() as u64 },
        );
    }
    for (id, req) in drained {
        if closing {
            let latency = req.submitted.elapsed();
            let _ = req.reply.send(RequestResult {
                value: 0,
                latency,
                error: Some("router shutting down".to_string()),
            });
        } else {
            inner.route(id, req);
        }
    }
    shard.reader_gone.store(true, Ordering::SeqCst);
}

/// One reactor-managed shard data connection (epoll plane).
struct ShardConn {
    shard_idx: usize,
    stream: TcpStream,
    dec: FrameDecoder,
    tx: ConnTx,
    /// Armed while a partial frame is buffered — the nonblocking
    /// equivalent of the blocking reader's [`FRAME_DEADLINE`].
    frame_deadline: Option<Instant>,
}

/// The epoll plane's counterpart of every [`reader_loop`] thread: one
/// loop multiplexing all shard data connections. Reads and decodes
/// inbound frames (dispatching through [`handle_shard_msg`]), enforces
/// the per-frame deadline, flushes transmit backlogs the nonblocking
/// writes left behind, and runs [`shard_conn_closed`] when a connection
/// dies — so failover, replay, and shutdown resolution are identical to
/// the threads plane.
fn router_reactor(inner: Arc<RouterInner>, reg_rx: Receiver<ReactorReg>) {
    let ep = match Epoll::new() {
        Ok(ep) => ep,
        Err(e) => {
            // Dropping reg_rx makes every subsequent connect_shard fail
            // loudly instead of silently queueing into nowhere.
            eprintln!("router: FATAL: cannot start epoll reactor: {e:#}");
            return;
        }
    };
    let mut table: HashMap<u64, ShardConn> = HashMap::new();
    let mut next_token = 0u64;
    let mut events: Vec<(u64, u32)> = Vec::new();
    while !inner.closing.load(Ordering::SeqCst) {
        // Adopt freshly connected shards.
        loop {
            match reg_rx.try_recv() {
                Ok(reg) => {
                    let token = next_token;
                    next_token += 1;
                    if ep.add(reg.stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, token).is_err() {
                        let _ = reg.stream.shutdown(std::net::Shutdown::Both);
                        if let Some(shard) = inner.shard(reg.shard_idx) {
                            shard_conn_closed(&inner, reg.shard_idx, &shard);
                        }
                        continue;
                    }
                    table.insert(
                        token,
                        ShardConn {
                            shard_idx: reg.shard_idx,
                            stream: reg.stream,
                            dec: FrameDecoder::new(reg.rx_seal),
                            tx: reg.tx,
                            frame_deadline: None,
                        },
                    );
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        ep.wait(ROUTER_TICK, &mut events);
        let mut closed: Vec<u64> = Vec::new();
        for &(token, _evs) in &events {
            let Some(conn) = table.get_mut(&token) else { continue };
            if !shard_read_ready(&inner, conn) {
                closed.push(token);
            }
        }
        // Per-tick sweep: frame-deadline expiry and leftover transmit
        // backlog (bytes a WouldBlock left queued in the ConnTx).
        let now = Instant::now();
        for (&token, conn) in table.iter_mut() {
            if closed.contains(&token) {
                continue;
            }
            if let Some(deadline) = conn.frame_deadline {
                if now >= deadline {
                    // Same trickler semantics (and accounting) as the
                    // blocking reader's FRAME_DEADLINE error.
                    if conn.dec.is_sealed() {
                        let e = anyhow!(
                            "frame incomplete after {FRAME_DEADLINE:?} (slow or stalled peer)"
                        );
                        shard_integrity_reject(&inner, conn.shard_idx, &e);
                    }
                    closed.push(token);
                    continue;
                }
            }
            if conn.tx.flush().is_err() {
                closed.push(token);
            }
        }
        for token in closed {
            if let Some(conn) = table.remove(&token) {
                let _ = ep.del(conn.stream.as_raw_fd());
                let _ = conn.stream.shutdown(std::net::Shutdown::Both);
                if let Some(shard) = inner.shard(conn.shard_idx) {
                    shard_conn_closed(&inner, conn.shard_idx, &shard);
                }
            }
        }
    }
    // Router shutdown: run the reader exit path for every remaining
    // connection so in-flight requests resolve with explicit shutdown
    // errors, exactly as each joined reader thread would have.
    for (_, conn) in table.drain() {
        let _ = ep.del(conn.stream.as_raw_fd());
        let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        if let Some(shard) = inner.shard(conn.shard_idx) {
            shard_conn_closed(&inner, conn.shard_idx, &shard);
        }
    }
}

/// Drain a readable shard connection into its decoder and dispatch
/// every complete message. Returns `false` when the connection must be
/// retired (EOF, read error, decode failure, protocol violation) — the
/// same conditions that end a blocking [`reader_loop`].
fn shard_read_ready(inner: &RouterInner, conn: &mut ShardConn) -> bool {
    let Some(shard) = inner.shard(conn.shard_idx) else { return false };
    let mut buf = [0u8; 16 * 1024];
    'read: loop {
        let n = {
            let mut r = &conn.stream;
            match r.read(&mut buf) {
                Ok(0) => return false,
                Ok(n) => n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break 'read,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        };
        conn.dec.push(&buf[..n]);
        loop {
            match conn.dec.try_next() {
                Ok(Some(msg)) => {
                    if !handle_shard_msg(inner, &shard, conn.shard_idx, msg) {
                        return false;
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    if conn.dec.is_sealed() {
                        shard_integrity_reject(inner, conn.shard_idx, &e);
                    }
                    return false;
                }
            }
        }
    }
    conn.frame_deadline = if conn.dec.mid_frame() {
        Some(conn.frame_deadline.unwrap_or_else(|| Instant::now() + FRAME_DEADLINE))
    } else {
        None
    };
    true
}

/// The router's self-healing loop: enforce data-path heartbeats,
/// revive downed shards, reconcile the spare pool, and sweep parked
/// requests (re-dispatch on membership changes, expire past the retry
/// window).
fn supervisor_loop(inner: Arc<RouterInner>) {
    while !inner.closing.load(Ordering::SeqCst) {
        std::thread::sleep(inner.cfg.probe_period);
        if inner.closing.load(Ordering::SeqCst) {
            break;
        }
        heartbeat_sweep(&inner);
        // Revival: re-probe each downed shard whose previous reader has
        // fully drained; a serving probe reopens the data connection and
        // returns the shard to its (stable) ring position.
        let n = inner.shards.read().unwrap().len();
        for i in 0..n {
            let Some(shard) = inner.shard(i) else { continue };
            // Placeholders have no endpoint to probe until claimed.
            if shard.is_placeholder()
                || shard.up.load(Ordering::SeqCst)
                || !shard.reader_gone.load(Ordering::SeqCst)
            {
                continue;
            }
            let addr = shard.addr();
            match probe_health_auth(&addr, inner.cfg.psk.as_ref()) {
                Ok((true, ..)) => match connect_shard(&inner, i) {
                    Ok(()) => {
                        eprintln!("router: shard {i} ({addr}) revived");
                        inner
                            .journal
                            .record_for(i as u32, EventKind::ShardRevive { shard: i as u32 });
                    }
                    Err(e) => eprintln!("router: shard {i} ({addr}) revival failed: {e:#}"),
                },
                // Unreachable or not serving (all workers retired):
                // stays down, probed again next tick.
                _ => {}
            }
        }
        inner.reconcile_spares();
        sweep_parked(&inner);
    }
}

/// Data-path heartbeats (wire v3): send a `Ping` on every live data
/// connection that has been silent past `heartbeat_period`, and mark
/// down any shard whose outstanding ping outlived `heartbeat_timeout`
/// — the only way a half-open peer (writes accepted, nothing ever read
/// back) is ever caught, since it produces neither a reader EOF nor a
/// write error. The down-mark shuts the socket, so the blocked reader
/// unblocks, drains the pending table, and replays every in-flight
/// request on the next live shard, exactly like a disconnect.
fn heartbeat_sweep(inner: &Arc<RouterInner>) {
    // Disabled (mixed-version fleets: a pre-v3 shard drops the
    // connection on its first ping, so during a shard upgrade the
    // operator turns heartbeats off rather than flapping old peers).
    if inner.cfg.heartbeat_period.is_zero() {
        return;
    }
    let n = inner.shards.read().unwrap().len();
    let now = Instant::now();
    for i in 0..n {
        let Some(shard) = inner.shard(i) else { continue };
        if !shard.up.load(Ordering::SeqCst) {
            continue;
        }
        let mut hb = shard.hb.lock().unwrap();
        if hb.outstanding != 0 {
            if now >= hb.deadline {
                hb.outstanding = 0;
                drop(hb);
                inner.hb_timeouts.fetch_add(1, Ordering::Relaxed);
                inner
                    .journal
                    .record_for(i as u32, EventKind::HeartbeatTimeout { shard: i as u32 });
                eprintln!(
                    "router: shard {i} ({}) missed its heartbeat deadline \
                     (half-open connection); marking down",
                    shard.addr()
                );
                inner.mark_down(i);
            }
        } else if now >= hb.next_ping {
            // Arm the deadline *before* writing, then release the hb
            // lock for the (possibly slow) socket write: the reader
            // must stay free to clear the outstanding ping — the pong
            // can race back between the write and any later bookkeeping
            // — and a wedged peer's blocked write must not hold hb
            // against it.
            let nonce = inner.hb_nonce.fetch_add(1, Ordering::Relaxed);
            hb.outstanding = nonce;
            hb.deadline = now + inner.cfg.heartbeat_timeout;
            hb.next_ping = now + inner.cfg.heartbeat_period;
            drop(hb);
            let wrote = match shard.writer.lock().unwrap().as_mut() {
                Some(writer) => writer.send(&Msg::Ping { nonce }).is_ok(),
                None => false,
            };
            if wrote {
                inner.hb_pings.fetch_add(1, Ordering::Relaxed);
            } else {
                inner.mark_down(i);
            }
        }
    }
}

/// Re-dispatch every parked request (with its tried-set cleared, so
/// revived shards are eligible again) and expire those past the retry
/// window with an explicit error. Re-dispatch is unconditional, not
/// gated on an observed membership change: a revival can land between a
/// failed ring walk and the park, and with nothing else moving the
/// epoch that request would otherwise sleep through a healthy fleet
/// until its deadline. A fruitless re-walk per tick is cheap; missing a
/// wakeup is an avoidable client-visible error.
fn sweep_parked(inner: &Arc<RouterInner>) {
    let now = Instant::now();
    let mut expired = Vec::new();
    let mut retry = Vec::new();
    {
        let mut parked = inner.parked.lock().unwrap();
        for (id, req) in parked.drain(..) {
            if now.duration_since(req.submitted) >= inner.cfg.retry_window {
                expired.push(req);
            } else {
                retry.push((id, req));
            }
        }
    }
    for (id, mut req) in retry {
        req.tried.clear();
        inner.route(id, req);
    }
    for req in expired {
        let latency = req.submitted.elapsed();
        let _ = req.reply.send(RequestResult {
            value: 0,
            latency,
            error: Some(format!(
                "no healthy shards within the {:?} retry window (tried {:?})",
                inner.cfg.retry_window, req.tried
            )),
        });
    }
}

/// Bind the registration listener and serve `Register` frames: each
/// connection carries one announcement and gets one `Welcome` ack.
fn spawn_registration_listener(
    inner: Arc<RouterInner>,
    addr: &str,
) -> Result<(SocketAddr, JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding registration listener to {addr}"))?;
    let bound = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let handle = std::thread::spawn(move || registration_loop(inner, listener));
    Ok((bound, handle))
}

fn registration_loop(inner: Arc<RouterInner>, listener: TcpListener) {
    while !inner.closing.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                // One short-lived thread per announcement: with the
                // whole fleet refreshing every REG_REFRESH, a single
                // silent client must not head-of-line-block everyone
                // else's re-registration — the handshake and the framed
                // read below are both deadline-bounded, so a slowloris
                // trickler costs one thread for a couple of seconds,
                // never the accept loop. During a router restart a
                // head-of-line stall would push recovery past the retry
                // window.
                let inner = inner.clone();
                std::thread::spawn(move || {
                    // Authenticate before the Register frame can touch
                    // the ring or the spare pool: an unauthenticated
                    // registrant is rejected here, counted, and never
                    // reaches `RouterInner::register`.
                    let pair = server_split(stream, inner.cfg.psk.as_ref(), Some(CONTROL_TIMEOUT));
                    let (mut reader, mut writer) = match pair {
                        Ok(p) => p,
                        Err(e) => {
                            inner.auth_rejects.fetch_add(1, Ordering::SeqCst);
                            inner.journal.record_for(SHARD_NONE, EventKind::AuthReject);
                            eprintln!("router: rejected registrant: {e:#}");
                            return;
                        }
                    };
                    let _ = writer.stream().set_write_timeout(Some(CONTROL_TIMEOUT));
                    match reader.recv() {
                        // The empty string is the placeholder sentinel
                        // in the slot table, so a nameless registrant
                        // is rejected outright: honoring it would let
                        // one frame hijack a slot reserved for a
                        // re-registering member.
                        Ok(Some(Msg::Register { name, addr, spare, prev }))
                            if !name.is_empty() && !inner.closing.load(Ordering::SeqCst) =>
                        {
                            let (shard, active) = inner.register(name, addr, spare, prev);
                            let welcome = Msg::Welcome { shard: shard as u32, active };
                            let _ = writer.send(&welcome);
                        }
                        // Nameless or non-Register traffic: drop it.
                        Ok(_) => {}
                        // Malformed — or, on a sealed connection,
                        // tampered/replayed — frames count as rejects
                        // when auth is on; the codec already refused
                        // the frame either way.
                        Err(_) => {
                            if reader.is_sealed() {
                                inner.auth_rejects.fetch_add(1, Ordering::SeqCst);
                                inner.journal.record_for(SHARD_NONE, EventKind::AuthReject);
                            }
                        }
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            // A registrant that reset before accept completed is its
            // problem, not the listener's: discovery must keep running
            // (a dead listener would strand every future restart).
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => {
                eprintln!("router: registration listener failed, stopping: {e}");
                break;
            }
        }
    }
}

/// One control request/reply over a short-lived, optionally
/// authenticated connection — the shared transport behind the
/// `probe_health` / `fetch_metrics` / `shutdown_endpoint` family.
fn control_roundtrip(addr: &str, psk: Option<&Psk>, req: &Msg) -> Result<Msg> {
    let stream = control_connect(addr)?;
    let (mut reader, mut writer) = client_split(stream, psk, Some(CONTROL_TIMEOUT))?;
    let _ = writer.stream().set_write_timeout(Some(CONTROL_TIMEOUT));
    writer.send(req)?;
    match reader.recv()? {
        Some(msg) => Ok(msg),
        None => bail!("peer closed the connection before replying"),
    }
}

/// Probe a shard endpoint's health over a short-lived connection.
pub fn probe_health(addr: &str) -> Result<(bool, u32, u32, u32)> {
    probe_health_auth(addr, None)
}

/// [`probe_health`] over an authenticated connection when a PSK is given.
pub fn probe_health_auth(addr: &str, psk: Option<&Psk>) -> Result<(bool, u32, u32, u32)> {
    match control_roundtrip(addr, psk, &Msg::HealthReq)? {
        Msg::HealthReply { serving, workers, routable, retired } => {
            Ok((serving, workers, routable, retired))
        }
        other => bail!("unexpected reply to HealthReq: {other:?}"),
    }
}

/// Fetch one shard's metrics over a short-lived connection.
pub fn fetch_metrics(addr: &str) -> Result<MetricsSnapshot> {
    fetch_metrics_auth(addr, None)
}

/// [`fetch_metrics`] over an authenticated connection when a PSK is given.
pub fn fetch_metrics_auth(addr: &str, psk: Option<&Psk>) -> Result<MetricsSnapshot> {
    match control_roundtrip(addr, psk, &Msg::MetricsReq)? {
        Msg::MetricsReply(m) => Ok(m),
        other => bail!("unexpected reply to MetricsReq: {other:?}"),
    }
}

/// Pull one shard's reliability events past `since` over a short-lived
/// connection (wire v5). Returns the events, the shard's next cursor
/// (pass it back as `since` on the next pull), and the shard's
/// `boot_epoch` (wire v6; 0 from a pre-v6 shard). A *changed* epoch
/// means the shard restarted and the cursor must reset to 0.
pub fn fetch_events(addr: &str, since: u64) -> Result<(Vec<Event>, u64, u64)> {
    fetch_events_auth(addr, None, since)
}

/// [`fetch_events`] over an authenticated connection when a PSK is
/// given.
pub fn fetch_events_auth(
    addr: &str,
    psk: Option<&Psk>,
    since: u64,
) -> Result<(Vec<Event>, u64, u64)> {
    match control_roundtrip(addr, psk, &Msg::Events { since })? {
        Msg::EventsReply { latest, events, boot_epoch } => Ok((events, latest, boot_epoch)),
        other => bail!("unexpected reply to Events: {other:?}"),
    }
}

/// Pull one shard's recorded stage spans over a short-lived connection
/// (wire v5).
pub fn fetch_spans(addr: &str) -> Result<Vec<TraceSpan>> {
    fetch_spans_auth(addr, None)
}

/// [`fetch_spans`] over an authenticated connection when a PSK is
/// given.
pub fn fetch_spans_auth(addr: &str, psk: Option<&Psk>) -> Result<Vec<TraceSpan>> {
    match control_roundtrip(addr, psk, &Msg::SpansReq)? {
        Msg::SpansReply { spans } => Ok(spans),
        other => bail!("unexpected reply to SpansReq: {other:?}"),
    }
}

/// Ask a fabric server process to stop serving (acked).
pub fn shutdown_endpoint(addr: &str) -> Result<()> {
    shutdown_endpoint_auth(addr, None)
}

/// [`shutdown_endpoint`] over an authenticated connection when a PSK is
/// given.
pub fn shutdown_endpoint_auth(addr: &str, psk: Option<&Psk>) -> Result<()> {
    match control_roundtrip(addr, psk, &Msg::Shutdown)? {
        Msg::ShutdownAck => Ok(()),
        other => bail!("unexpected reply to Shutdown: {other:?}"),
    }
}

/// FNV-1a — stable across runs and platforms (the ring must not depend
/// on `DefaultHasher`'s randomized keys).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    hash
}

fn hash_kind(kind: FunctionKind) -> u64 {
    fnv64(kind.name().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_inner(n: usize, spares: usize) -> RouterInner {
        let mut shards: Vec<Arc<ShardState>> = (0..n)
            .map(|i| ShardState::new(format!("m{i}"), format!("127.0.0.1:{i}"), false))
            .collect();
        shards.extend(
            (0..spares)
                .map(|i| ShardState::new(format!("s{i}"), format!("127.0.0.1:9{i}"), true)),
        );
        for s in &shards {
            s.up.store(true, Ordering::SeqCst);
        }
        let inner = RouterInner {
            cfg: RouterConfig::default(),
            shards: RwLock::new(shards),
            ring: RwLock::new(Vec::new()),
            epoch: AtomicU64::new(0),
            parked: Mutex::new(Vec::new()),
            readers: Mutex::new(Vec::new()),
            reactor_tx: Mutex::new(None),
            next_id: AtomicU64::new(1),
            hb_nonce: AtomicU64::new(1),
            hb_pings: AtomicU64::new(0),
            hb_pongs: AtomicU64::new(0),
            hb_timeouts: AtomicU64::new(0),
            auth_rejects: AtomicU64::new(0),
            tracer: Tracer::new(0, 16),
            journal: Arc::new(EventJournal::new(16)),
            fleet: Mutex::new(FleetEvents::default()),
            closing: AtomicBool::new(false),
        };
        inner.rebuild_ring();
        inner
    }

    #[test]
    fn ring_is_deterministic_and_covers_all_shards() {
        let inner = test_inner(3, 0);
        // Every walk visits each shard exactly once, and the first hop
        // is a pure function of the kind.
        for bits in 1..=32 {
            let order = inner.ring_order(hash_kind(FunctionKind::Add(bits)));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2], "walk {order:?}");
            assert_eq!(
                inner.shard_for(FunctionKind::Add(bits)),
                Some(order[0]),
                "shard_for is the walk head"
            );
        }
        // Many kinds spread over more than one shard.
        let first: Vec<usize> = (1..=32)
            .map(|bits| inner.shard_for(FunctionKind::Add(bits)).unwrap())
            .collect();
        assert!(
            first.iter().any(|&s| s != first[0]),
            "32 kinds must not all hash to one shard: {first:?}"
        );
        // Downing the preferred shard fails over to the next on the walk.
        let k = FunctionKind::Xor(8);
        let preferred = inner.shard_for(k).unwrap();
        inner.shard(preferred).unwrap().up.store(false, Ordering::SeqCst);
        let fallback = inner.shard_for(k).unwrap();
        assert_ne!(fallback, preferred);
        assert_eq!(inner.ring_order(hash_kind(k))[1], fallback);
    }

    #[test]
    fn spares_stay_out_of_the_ring_until_promoted_and_demote_cleanly() {
        let inner = test_inner(2, 1);
        let kinds: Vec<FunctionKind> =
            (1..=32).flat_map(|b| [FunctionKind::Add(b), FunctionKind::Xor(b)]).collect();
        let walks: Vec<Vec<usize>> =
            kinds.iter().map(|&k| inner.ring_order(hash_kind(k))).collect();
        for w in &walks {
            assert!(!w.contains(&2), "idle spare must stay out of the ring: {w:?}");
        }
        // Member 1 goes down: the spare is promoted and appears on
        // walks; member placement (relative order of 0 and 1) persists.
        inner.shard(1).unwrap().up.store(false, Ordering::SeqCst);
        inner.reconcile_spares();
        assert!(inner.shard(2).unwrap().promoted.load(Ordering::SeqCst));
        let during: Vec<Vec<usize>> =
            kinds.iter().map(|&k| inner.ring_order(hash_kind(k))).collect();
        assert!(during.iter().any(|w| w.contains(&2)), "promoted spare joins the ring");
        for (before, now) in walks.iter().zip(&during) {
            let filtered: Vec<usize> = now.iter().copied().filter(|&s| s != 2).collect();
            assert_eq!(&filtered, before, "members keep their relative ring order");
        }
        // Member 1 revives: the spare demotes and every walk is
        // bit-identical to never having failed.
        inner.shard(1).unwrap().up.store(true, Ordering::SeqCst);
        inner.reconcile_spares();
        assert!(!inner.shard(2).unwrap().promoted.load(Ordering::SeqCst));
        let after: Vec<Vec<usize>> =
            kinds.iter().map(|&k| inner.ring_order(hash_kind(k))).collect();
        assert_eq!(after, walks, "down/revive cycle must not move any kind");
    }

    #[test]
    fn registration_assigns_stable_slots_and_reuse_by_name() {
        let inner = test_inner(1, 0);
        let (i1, active1) = inner.register("alpha".into(), "127.0.0.1:7001".into(), false, None);
        assert_eq!((i1, active1), (1, true));
        let (i2, active2) = inner.register("sp".into(), "127.0.0.1:7002".into(), true, None);
        assert_eq!((i2, active2), (2, false), "spares start outside the ring");
        // A restarted process re-registers under its name at a new port
        // and reclaims the same slot.
        let (i3, _) = inner.register("alpha".into(), "127.0.0.1:7099".into(), false, None);
        assert_eq!(i3, 1);
        assert_eq!(inner.shard(1).unwrap().addr(), "127.0.0.1:7099");
        assert_eq!(inner.shards.read().unwrap().len(), 3);
        // A periodic refresh (same name, same endpoint) is a silent
        // no-op: same slot, no epoch bump, no membership change.
        let epoch = inner.epoch.load(Ordering::SeqCst);
        let (i4, _) = inner.register("alpha".into(), "127.0.0.1:7099".into(), false, Some(1));
        assert_eq!(i4, 1);
        assert_eq!(inner.epoch.load(Ordering::SeqCst), epoch, "refresh must not bump the epoch");
        assert_eq!(inner.shards.read().unwrap().len(), 3);
    }

    #[test]
    fn prev_slot_claims_rebuild_identical_rings_in_any_order() {
        // The ring a 3-member router built, by stable index.
        let reference = test_inner(3, 0);
        let kinds: Vec<FunctionKind> =
            (1..=32).flat_map(|b| [FunctionKind::Add(b), FunctionKind::Xor(b)]).collect();
        let ref_walks: Vec<Vec<usize>> =
            kinds.iter().map(|&k| reference.ring_order(hash_kind(k))).collect();
        // A fresh (restarted) router sees the fleet re-register in an
        // arbitrary order, each shard carrying its previous index.
        for order in [[2usize, 0, 1], [1, 2, 0], [0, 1, 2]] {
            let rebuilt = test_inner(0, 0);
            for &i in &order {
                let (got, active) = rebuilt.register(
                    format!("m{i}"),
                    format!("127.0.0.1:{i}"),
                    false,
                    Some(i as u32),
                );
                assert_eq!((got, active), (i, true), "slot reclaimed by prev index");
            }
            assert_eq!(rebuilt.shards.read().unwrap().len(), 3);
            assert!(
                rebuilt.shards.read().unwrap().iter().all(|s| !s.is_placeholder()),
                "every placeholder is claimed once the fleet re-registers"
            );
            let walks: Vec<Vec<usize>> =
                kinds.iter().map(|&k| rebuilt.ring_order(hash_kind(k))).collect();
            assert_eq!(walks, ref_walks, "ring rebuilt bit-identically (order {order:?})");
        }
    }

    #[test]
    fn stale_prev_hints_fall_through_to_fresh_slots() {
        let inner = test_inner(0, 0);
        let (i0, _) = inner.register("a".into(), "127.0.0.1:1".into(), false, Some(0));
        assert_eq!(i0, 0);
        // A different shard claiming the same previous index cannot
        // evict the occupant: it gets a fresh slot instead.
        let (i1, _) = inner.register("b".into(), "127.0.0.1:2".into(), false, Some(0));
        assert_eq!(i1, 1, "occupied slot is never stolen");
        // A spare reclaiming a reserved high slot stays out of the ring.
        let (i3, active3) = inner.register("sp".into(), "127.0.0.1:3".into(), true, Some(3));
        assert_eq!((i3, active3), (3, false));
        let shards = inner.shards.read().unwrap();
        assert!(shards[2].is_placeholder(), "slot 2 stays reserved for its member");
        assert!(!shards[3].in_ring(), "reclaimed spare slot stays out of the ring");
        drop(shards);
        // A hint beyond any plausible fleet (garbage or a hostile
        // frame) is ignored rather than allocated: fresh slot, no
        // placeholder flood.
        let (i4, _) = inner.register("c".into(), "127.0.0.1:4".into(), false, Some(u32::MAX));
        assert_eq!(i4, 4);
        assert_eq!(inner.shards.read().unwrap().len(), 5);
        // Defense in depth behind the listener's empty-name rejection:
        // an empty name never matches the reserved placeholder at slot
        // 2 (the empty string is the placeholder sentinel).
        let (i5, _) = inner.register(String::new(), "127.0.0.1:66".into(), false, None);
        assert_eq!(i5, 5, "an empty-name registrant must not hijack a reserved slot");
        assert!(inner.shards.read().unwrap()[2].is_placeholder(), "slot 2 still reserved");
    }

    #[test]
    fn unclaimed_placeholders_are_inert_for_spares() {
        // Member 0 and spare 1, both live; a prev=3 claim reserves a
        // placeholder at slot 2 that no one ever claims (a stale hint
        // from an old, larger fleet).
        let inner = test_inner(1, 1);
        inner.register("far".into(), "127.0.0.1:9".into(), false, Some(3));
        inner.shard(3).unwrap().up.store(true, Ordering::SeqCst);
        inner.reconcile_spares();
        assert!(
            !inner.shard(1).unwrap().promoted.load(Ordering::SeqCst),
            "a reserved-but-unclaimed slot must not consume a hot spare"
        );
    }
}
