//! The fabric router: a client-side shard fan-out implementing
//! [`Submitter`] over a *dynamic* fleet of fabric servers.
//!
//! **Sharding** is FunctionKind-aware consistent hashing: each ring
//! member contributes virtual nodes to a hash ring and a request's kind
//! picks the first live shard at or after its hash. Same-kind requests
//! land on the same shard, so the per-shard coordinator's dynamic
//! batching sees exactly the stream it would see in-process; losing a
//! shard only remaps the kinds it owned (classic consistent-hashing
//! locality). The ring is keyed by *stable shard index*, so placement
//! after a down/revive cycle is bit-identical to never having failed.
//!
//! **Failover** is health-driven: a shard is marked down when its
//! connection drops, when a write fails, or when it answers a request
//! with an all-workers-retired capacity error. In-flight requests on a
//! downed shard are re-routed to the next live shard on the ring
//! (at-least-once execution: results are deterministic functions, so
//! replays are safe). During a *total* outage requests are parked for a
//! bounded [`RouterConfig::retry_window`] — shards are often seconds
//! from revival — and only resolve to an explicit error once the window
//! expires. Clients never hang, mirroring the in-process coordinator's
//! contract.
//!
//! **Revival** (§Health, one layer up): membership is not a one-shot
//! property. A supervisor thread periodically re-probes downed shards
//! ([`probe_health`] over short-lived control connections), reopens the
//! data connection, respawns the reader, and atomically returns the
//! shard to ring routing — the fleet-level analogue of the per-crossbar
//! scrub -> remap -> activate-spare loop.
//!
//! **Discovery** is registration-based when [`RouterConfig::listen`] is
//! set: `fabric-serve` processes announce themselves with a `Register`
//! frame (stable `name`, current endpoint, spare flag) instead of a
//! static `--shards` list; a restarted shard re-registering under the
//! same name reclaims its ring slot even at a new port. Registered
//! **hot spares** stay connected but outside the ring until a member is
//! marked down; then they are promoted in (and demoted back once the
//! member revives), mirroring `CoordinatorConfig::spare_workers`.
//!
//! **Metrics** are fetched per shard over short-lived control
//! connections and merged ([`MetricsSnapshot::merge`]) into one fleet
//! view stamped with `shards_total`/`shards_down`, so a degraded fleet
//! is distinguishable from a healthy smaller one.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::{MetricsSnapshot, NO_CAPACITY_ERROR, RequestResult, Submitter};
use crate::mmpu::FunctionKind;

use super::wire::{read_msg, write_msg, Msg};

/// Virtual nodes per shard on the hash ring.
const RING_VNODES: usize = 16;

/// Bound on control-plane connect/read/write, so a hung shard (host
/// down, blackholed traffic) cannot freeze a fleet metrics, health or
/// revival probe. The data path fails over on *closed* connections
/// (reader EOF / write error); a silently blackholed peer that keeps
/// its connection half-open is only caught by the operator or a control
/// probe today — data-path heartbeats are named multi-machine work in
/// ROADMAP §Scale.
const CONTROL_TIMEOUT: Duration = Duration::from_secs(5);

/// Short-lived control connection with timeouts applied.
pub(crate) fn control_connect(addr: &str) -> Result<TcpStream> {
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr} resolves to no address"))?;
    let stream = TcpStream::connect_timeout(&sock, CONTROL_TIMEOUT)
        .with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_read_timeout(Some(CONTROL_TIMEOUT));
    let _ = stream.set_write_timeout(Some(CONTROL_TIMEOUT));
    Ok(stream)
}

/// Tunables for the router's self-healing membership machinery.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Supervisor tick: how often downed shards are re-probed for
    /// revival, spares reconciled, and parked requests swept.
    pub probe_period: Duration,
    /// How long a request submitted during a total outage may wait for
    /// a revival before resolving to an explicit "no healthy shards"
    /// error (measured from submission; default a few probe periods).
    pub retry_window: Duration,
    /// Bind address of the registration listener (`None`: static
    /// membership only). Shards announce themselves here with
    /// `Register` frames; port 0 binds an ephemeral port (see
    /// [`Router::registration_addr`]).
    pub listen: Option<String>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            probe_period: Duration::from_millis(250),
            retry_window: Duration::from_millis(1000),
            listen: None,
        }
    }
}

/// A request in flight on some shard, retaining everything needed to
/// replay it elsewhere.
struct PendingReq {
    kind: FunctionKind,
    a: u64,
    b: u64,
    reply: Sender<RequestResult>,
    submitted: Instant,
    /// Shards already tried (failover never loops within one attempt;
    /// cleared when a parked request is re-dispatched after a
    /// membership change).
    tried: Vec<usize>,
}

struct ShardState {
    /// Stable identity (the registration key; static shards use their
    /// address). A restarting process re-registers under the same name
    /// to reclaim this slot.
    name: String,
    /// Current endpoint — re-registration after a restart may move it.
    addr: Mutex<String>,
    /// Registered as a hot spare: connected but outside the ring until
    /// promoted to cover a downed member.
    spare: bool,
    /// Spare currently promoted into the ring.
    promoted: AtomicBool,
    up: AtomicBool,
    /// The previous connection's reader has fully drained its pending
    /// table — only then may the supervisor open a new connection (no
    /// two readers ever share one pending table).
    reader_gone: AtomicBool,
    /// Write half of the data connection (`None` once down).
    writer: Mutex<Option<TcpStream>>,
    /// In-flight requests keyed by wire id.
    pending: Mutex<HashMap<u64, PendingReq>>,
}

impl ShardState {
    fn new(name: String, addr: String, spare: bool) -> Arc<Self> {
        Arc::new(Self {
            name,
            addr: Mutex::new(addr),
            spare,
            promoted: AtomicBool::new(false),
            up: AtomicBool::new(false),
            reader_gone: AtomicBool::new(true),
            writer: Mutex::new(None),
            pending: Mutex::new(HashMap::new()),
        })
    }

    fn addr(&self) -> String {
        self.addr.lock().unwrap().clone()
    }

    /// In the routing ring right now (members always; spares only while
    /// promoted).
    fn in_ring(&self) -> bool {
        !self.spare || self.promoted.load(Ordering::SeqCst)
    }
}

struct RouterInner {
    cfg: RouterConfig,
    /// Shard slots; grows on registration, never shrinks, so indices —
    /// and therefore ring placement — are stable for the router's
    /// lifetime.
    shards: RwLock<Vec<Arc<ShardState>>>,
    /// Sorted (hash, shard) ring over the current members. Keyed by
    /// shard *index* so the kind->shard map is stable across runs,
    /// ports and down/revive cycles.
    ring: RwLock<Vec<(u64, usize)>>,
    /// Ring-membership epoch: bumped on every down / revive / promote /
    /// demote / (re-)register event, so tests and operators can watch
    /// membership transitions.
    epoch: AtomicU64,
    /// Requests that found no live shard, awaiting a revival or their
    /// retry-window deadline.
    parked: Mutex<Vec<(u64, PendingReq)>>,
    readers: Mutex<Vec<JoinHandle<()>>>,
    next_id: AtomicU64,
    closing: AtomicBool,
}

/// The sharded remote submitter.
pub struct Router {
    inner: Arc<RouterInner>,
    supervisor: Option<JoinHandle<()>>,
    reg_handle: Option<JoinHandle<()>>,
    reg_addr: Option<SocketAddr>,
}

impl Router {
    /// Connect to a static list of shard endpoints with default tuning.
    /// Unreachable shards are marked down (the supervisor keeps probing
    /// them); at least one must be reachable.
    pub fn connect(addrs: &[String]) -> Result<Self> {
        Self::with_config(addrs, RouterConfig::default())
    }

    /// Connect with explicit tuning. `addrs` may be empty when
    /// `cfg.listen` is set — the fleet is then discovered entirely
    /// through shard registration.
    pub fn with_config(addrs: &[String], cfg: RouterConfig) -> Result<Self> {
        ensure!(
            !addrs.is_empty() || cfg.listen.is_some(),
            "router needs at least one shard address or a registration listener"
        );
        let shards: Vec<Arc<ShardState>> =
            addrs.iter().map(|a| ShardState::new(a.clone(), a.clone(), false)).collect();
        let inner = Arc::new(RouterInner {
            cfg: cfg.clone(),
            shards: RwLock::new(shards),
            ring: RwLock::new(Vec::new()),
            epoch: AtomicU64::new(0),
            parked: Mutex::new(Vec::new()),
            readers: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
            closing: AtomicBool::new(false),
        });
        inner.rebuild_ring();
        for i in 0..addrs.len() {
            if let Err(e) = connect_shard(&inner, i) {
                eprintln!("router: shard {i} ({}) unreachable at connect: {e:#}", addrs[i]);
            }
        }
        if !addrs.is_empty() {
            ensure!(inner.live_shards() > 0, "no reachable shard among {addrs:?}");
        }
        let (reg_addr, reg_handle) = match &cfg.listen {
            Some(addr) => match spawn_registration_listener(inner.clone(), addr) {
                Ok((bound, handle)) => (Some(bound), Some(handle)),
                Err(e) => {
                    // Unwind the connections already opened so their
                    // reader threads exit instead of leaking.
                    inner.closing.store(true, Ordering::SeqCst);
                    for i in 0..inner.shards.read().unwrap().len() {
                        inner.mark_down(i);
                    }
                    return Err(e);
                }
            },
            None => (None, None),
        };
        let supervisor = {
            let inner = inner.clone();
            Some(std::thread::spawn(move || supervisor_loop(inner)))
        };
        Ok(Self { inner, supervisor, reg_handle, reg_addr })
    }

    /// The registration listener's bound address (resolves port 0), or
    /// `None` without one.
    pub fn registration_addr(&self) -> Option<SocketAddr> {
        self.reg_addr
    }

    /// The shard a kind currently routes to (None with every shard
    /// down). Exposed for tests and fleet introspection.
    pub fn shard_for(&self, kind: FunctionKind) -> Option<usize> {
        self.inner.shard_for(kind)
    }

    /// The kind's full ring preference order over the *current*
    /// membership, liveness ignored (placement, not routing). After a
    /// down/revive cycle this must be identical to never having failed.
    pub fn ring_walk(&self, kind: FunctionKind) -> Vec<usize> {
        self.inner.ring_order(hash_kind(kind))
    }

    /// Addresses this router currently knows, in stable shard order.
    pub fn shard_addrs(&self) -> Vec<String> {
        self.inner.shards.read().unwrap().iter().map(|s| s.addr()).collect()
    }

    /// Total shard slots (static + registered, spares included).
    pub fn shard_count(&self) -> usize {
        self.inner.shards.read().unwrap().len()
    }

    /// Shards with a live data connection right now (spares included).
    pub fn live_shards(&self) -> usize {
        self.inner.live_shards()
    }

    /// Current ring-membership epoch (bumps on every down / revive /
    /// promote / demote / register event).
    pub fn membership_epoch(&self) -> u64 {
        self.inner.epoch.load(Ordering::SeqCst)
    }

    /// CLI bootstrap shared by `remus serve`/`fabric-route` and the
    /// serve example: with a registration listener configured, print
    /// its address (for `fabric-serve --register`) and wait for
    /// `min_live` shards before the caller drives load, warning (not
    /// failing) on timeout. No-op without a listener.
    pub fn announce_and_wait(&self, min_live: usize, timeout: Duration, ctx: &str) {
        let Some(reg) = self.registration_addr() else { return };
        println!("REGISTRATION {reg}");
        if !self.wait_for_live(min_live, timeout) {
            eprintln!(
                "{ctx}: only {}/{min_live} shards live after {timeout:?}; continuing",
                self.live_shards()
            );
        }
    }

    /// Block until at least `n` shards are live, or `timeout` expires.
    /// Returns whether the target was reached (used by `fabric-route
    /// --listen-reg` before driving load, and by tests).
    pub fn wait_for_live(&self, n: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while self.live_shards() < n {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        true
    }

    pub fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        let (tx, rx) = channel();
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.inner.route(
            id,
            PendingReq { kind, a, b, reply: tx, submitted: Instant::now(), tried: Vec::new() },
        );
        rx
    }

    /// Merged fleet metrics: every shard (even one marked down for
    /// routing — its server may still answer control traffic) is probed
    /// over a short-lived connection; unreachable shards are skipped
    /// but still counted in `shards_total`/`shards_down`, so a degraded
    /// fleet never masquerades as a healthy smaller one. Probes run
    /// concurrently, so a fleet of dead shards costs one
    /// `CONTROL_TIMEOUT`, not a serial sum; the merge keeps shard order.
    pub fn metrics(&self) -> MetricsSnapshot {
        let shards: Vec<Arc<ShardState>> = self.inner.shards.read().unwrap().clone();
        let probes: Vec<_> = shards
            .iter()
            .map(|shard| {
                let addr = shard.addr();
                std::thread::spawn(move || {
                    let m = fetch_metrics(&addr);
                    (addr, m)
                })
            })
            .collect();
        let mut merged = MetricsSnapshot::default();
        for probe in probes {
            match probe.join() {
                Ok((_, Ok(m))) => merged.merge(&m),
                Ok((addr, Err(e))) => {
                    eprintln!("router: metrics from {addr} unavailable: {e:#}")
                }
                Err(_) => {}
            }
        }
        merged.shards_total = shards.len() as u64;
        merged.shards_down = shards.iter().filter(|s| !s.up.load(Ordering::SeqCst)).count() as u64;
        merged
    }

    pub fn is_serving(&self) -> bool {
        self.live_shards() > 0
    }

    /// Close every shard connection, stop the supervisor and
    /// registration listener, and join the reader threads. In-flight
    /// and parked requests resolve with explicit shutdown errors.
    pub fn shutdown(mut self) {
        self.inner.closing.store(true, Ordering::SeqCst);
        let n = self.inner.shards.read().unwrap().len();
        for i in 0..n {
            self.inner.mark_down(i);
        }
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        if let Some(h) = self.reg_handle.take() {
            let _ = h.join();
        }
        // The supervisor may have completed a revival racing the close
        // above; with it joined, one more pass closes any connection it
        // opened so no reader blocks the joins below.
        for i in 0..self.inner.shards.read().unwrap().len() {
            self.inner.mark_down(i);
        }
        let readers: Vec<_> = self.inner.readers.lock().unwrap().drain(..).collect();
        for h in readers {
            let _ = h.join();
        }
        let parked: Vec<_> = self.inner.parked.lock().unwrap().drain(..).collect();
        for (_, req) in parked {
            let latency = req.submitted.elapsed();
            let _ = req.reply.send(RequestResult {
                value: 0,
                latency,
                error: Some("router shutting down".to_string()),
            });
        }
    }
}

impl Submitter for Router {
    fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        Router::submit(self, kind, a, b)
    }

    fn metrics(&self) -> MetricsSnapshot {
        Router::metrics(self)
    }

    fn is_serving(&self) -> bool {
        Router::is_serving(self)
    }
}

impl RouterInner {
    fn shard(&self, i: usize) -> Option<Arc<ShardState>> {
        self.shards.read().unwrap().get(i).cloned()
    }

    fn live_shards(&self) -> usize {
        self.shards.read().unwrap().iter().filter(|s| s.up.load(Ordering::SeqCst)).count()
    }

    fn bump_epoch(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
    }

    /// Regenerate the ring from current membership (members + promoted
    /// spares). Vnode hashes depend only on the stable shard index, so
    /// regenerating after a revive/demote cycle reproduces the original
    /// ring bit for bit.
    fn rebuild_ring(&self) {
        let shards = self.shards.read().unwrap();
        let mut ring = Vec::with_capacity(shards.len() * RING_VNODES);
        for (i, s) in shards.iter().enumerate() {
            if !s.in_ring() {
                continue;
            }
            for vnode in 0..RING_VNODES {
                ring.push((fnv64(format!("shard{i}/vnode{vnode}").as_bytes()), i));
            }
        }
        drop(shards);
        ring.sort_unstable();
        *self.ring.write().unwrap() = ring;
    }

    /// Walk shard indices in ring order starting at `hash` (vnodes
    /// deduplicated), yielding each ring member once.
    fn ring_order(&self, hash: u64) -> Vec<usize> {
        let ring = self.ring.read().unwrap();
        if ring.is_empty() {
            return Vec::new();
        }
        let start = ring.partition_point(|&(h, _)| h < hash);
        // O(1) dedup bitmap sized from the ring itself (every routing
        // decision walks this; a linear `contains` would make it
        // quadratic in fleet size).
        let max_idx = ring.iter().map(|&(_, s)| s).max().unwrap_or(0);
        let mut seen = vec![false; max_idx + 1];
        let mut order = Vec::new();
        for k in 0..ring.len() {
            let shard = ring[(start + k) % ring.len()].1;
            if !seen[shard] {
                seen[shard] = true;
                order.push(shard);
            }
        }
        order
    }

    fn shard_for(&self, kind: FunctionKind) -> Option<usize> {
        let shards = self.shards.read().unwrap();
        self.ring_order(hash_kind(kind))
            .into_iter()
            .find(|&s| shards.get(s).is_some_and(|sh| sh.up.load(Ordering::SeqCst)))
    }

    /// Dispatch (or re-dispatch) a request to the first live shard on
    /// its kind's ring walk that hasn't been tried yet. With none left:
    /// park it for the retry window (a revival may be seconds away), or
    /// resolve it with an explicit error once the window has expired.
    fn route(&self, id: u64, mut req: PendingReq) {
        for shard_idx in self.ring_order(hash_kind(req.kind)) {
            if req.tried.contains(&shard_idx) {
                continue;
            }
            let Some(shard) = self.shard(shard_idx) else { continue };
            if !shard.up.load(Ordering::SeqCst) {
                continue;
            }
            req.tried.push(shard_idx);
            let msg = Msg::Submit { id, kind: req.kind, a: req.a, b: req.b };
            // Register before writing so the reader can match a fast
            // reply; reclaim on write failure.
            shard.pending.lock().unwrap().insert(id, req);
            let wrote = match shard.writer.lock().unwrap().as_mut() {
                Some(stream) => write_msg(stream, &msg).is_ok(),
                None => false,
            };
            if wrote {
                return;
            }
            self.mark_down(shard_idx);
            req = match shard.pending.lock().unwrap().remove(&id) {
                Some(r) => r,
                // The reader drained it first and is re-routing it.
                None => return,
            };
        }
        // Total outage on this walk: hold the request for the bounded
        // retry window instead of failing instantly — the supervisor
        // re-dispatches it on the next membership change and expires it
        // at the deadline.
        if !self.closing.load(Ordering::SeqCst) && req.submitted.elapsed() < self.cfg.retry_window
        {
            self.parked.lock().unwrap().push((id, req));
            return;
        }
        let latency = req.submitted.elapsed();
        let _ = req.reply.send(RequestResult {
            value: 0,
            latency,
            error: Some(format!("no healthy shards (tried {:?})", req.tried)),
        });
    }

    /// Take a shard out of routing, unblock its reader, and promote a
    /// spare to cover it.
    fn mark_down(&self, i: usize) {
        let Some(shard) = self.shard(i) else { return };
        let was_up = shard.up.swap(false, Ordering::SeqCst);
        if let Some(w) = shard.writer.lock().unwrap().take() {
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
        if was_up {
            self.bump_epoch();
            if !self.closing.load(Ordering::SeqCst) {
                eprintln!("router: shard {i} ({}) marked down", shard.addr());
                self.reconcile_spares();
            }
        }
    }

    /// Promote exactly as many (live) spares into the ring as there are
    /// downed members; demote the rest. Idempotent and deterministic
    /// (stable index order), called on every membership event — so a
    /// revival automatically demotes the spare that covered it.
    fn reconcile_spares(&self) {
        if self.closing.load(Ordering::SeqCst) {
            return;
        }
        let shards = self.shards.read().unwrap();
        let mut need =
            shards.iter().filter(|s| !s.spare && !s.up.load(Ordering::SeqCst)).count();
        let mut changed = false;
        for (i, s) in shards.iter().enumerate() {
            if !s.spare {
                continue;
            }
            let want = need > 0 && s.up.load(Ordering::SeqCst);
            if want {
                need -= 1;
            }
            if s.promoted.swap(want, Ordering::SeqCst) != want {
                changed = true;
                eprintln!(
                    "router: spare shard {i} ({}) {}",
                    s.addr(),
                    if want { "promoted into the ring" } else { "demoted back to the pool" }
                );
            }
        }
        drop(shards);
        if changed {
            self.rebuild_ring();
            self.bump_epoch();
        }
    }

    /// Add (or refresh) a shard from a `Register` frame. Returns the
    /// stable index and whether the shard is immediately in the ring.
    fn register(&self, name: String, addr: String, spare: bool) -> (usize, bool) {
        let mut shards = self.shards.write().unwrap();
        if let Some((i, s)) = shards.iter().enumerate().find(|(_, s)| s.name == name) {
            // Re-registration: the shard process restarted (possibly on
            // a new port) and reclaims its slot; the supervisor
            // reconnects once the old connection's reader has drained.
            // The member/spare role is fixed for the slot's lifetime —
            // the Welcome ack reports the slot's actual state.
            if s.spare != spare {
                eprintln!(
                    "router: shard {i} ({name}) re-registered asking to be a {}, but its \
                     slot is a {}; role is fixed per name",
                    if spare { "spare" } else { "member" },
                    if s.spare { "spare" } else { "member" }
                );
            }
            let active = s.in_ring();
            *s.addr.lock().unwrap() = addr.clone();
            drop(shards);
            self.bump_epoch();
            eprintln!("router: shard {i} ({name}) re-registered at {addr}");
            return (i, active);
        }
        let idx = shards.len();
        shards.push(ShardState::new(name.clone(), addr.clone(), spare));
        drop(shards);
        if !spare {
            self.rebuild_ring();
        }
        self.bump_epoch();
        eprintln!(
            "router: shard {idx} ({name}) registered at {addr}{}",
            if spare { " as a hot spare" } else { "" }
        );
        (idx, !spare)
    }
}

/// Open shard `i`'s data connection, store the write half, respawn the
/// reader, and atomically return the shard to routing.
fn connect_shard(inner: &Arc<RouterInner>, i: usize) -> Result<()> {
    ensure!(!inner.closing.load(Ordering::SeqCst), "router shutting down");
    let shard = inner.shard(i).ok_or_else(|| anyhow!("no shard {i}"))?;
    ensure!(
        shard.reader_gone.load(Ordering::SeqCst),
        "shard {i} still has a reader draining its previous connection"
    );
    let addr = shard.addr();
    let stream =
        TcpStream::connect(addr.as_str()).with_context(|| format!("connecting to shard {addr}"))?;
    let _ = stream.set_nodelay(true);
    let write_half = stream.try_clone()?;
    *shard.writer.lock().unwrap() = Some(write_half);
    shard.reader_gone.store(false, Ordering::SeqCst);
    shard.up.store(true, Ordering::SeqCst);
    inner.bump_epoch();
    let inner2 = inner.clone();
    let handle = std::thread::spawn(move || reader_loop(inner2, i, stream));
    let mut readers = inner.readers.lock().unwrap();
    // Reap finished readers so a long-lived router reviving shards many
    // times does not accumulate a handle per connection.
    readers.retain(|h| !h.is_finished());
    readers.push(handle);
    Ok(())
}

/// Per-shard reader: matches `Result` frames to pending requests, turns
/// capacity errors into failovers, and on disconnect re-routes whatever
/// was still in flight, then hands the slot back for revival.
fn reader_loop(inner: Arc<RouterInner>, shard_idx: usize, mut read_half: TcpStream) {
    let Some(shard) = inner.shard(shard_idx) else { return };
    loop {
        match read_msg(&mut read_half) {
            Ok(Some(Msg::Result { id, value, latency_us: _, error })) => {
                let req = shard.pending.lock().unwrap().remove(&id);
                let Some(req) = req else { continue };
                // An all-workers-retired shard answers every request
                // with the coordinator's capacity error: mark it down
                // and fail the request over instead of delivering it.
                let capacity_error =
                    error.as_deref().is_some_and(|e| e.contains(NO_CAPACITY_ERROR));
                if capacity_error && !inner.closing.load(Ordering::SeqCst) {
                    inner.mark_down(shard_idx);
                    inner.route(id, req);
                    continue;
                }
                let latency = req.submitted.elapsed();
                let _ = req.reply.send(RequestResult { value, latency, error });
            }
            // Control replies ride dedicated connections; anything else
            // here is a protocol violation — drop the connection.
            Ok(Some(_)) => break,
            Ok(None) | Err(_) => break,
        }
    }
    inner.mark_down(shard_idx);
    // Fail over (or, at router shutdown, resolve) the in-flight tail.
    let drained: Vec<(u64, PendingReq)> = shard.pending.lock().unwrap().drain().collect();
    let closing = inner.closing.load(Ordering::SeqCst);
    if !drained.is_empty() && !closing {
        eprintln!(
            "router: shard {shard_idx} disconnected with {} in flight; rerouting",
            drained.len()
        );
    }
    for (id, req) in drained {
        if closing {
            let latency = req.submitted.elapsed();
            let _ = req.reply.send(RequestResult {
                value: 0,
                latency,
                error: Some("router shutting down".to_string()),
            });
        } else {
            inner.route(id, req);
        }
    }
    // Only now may the supervisor open a replacement connection: the
    // pending table is empty and no other thread will touch it on this
    // slot's behalf.
    shard.reader_gone.store(true, Ordering::SeqCst);
}

/// The router's self-healing loop: revive downed shards, reconcile the
/// spare pool, and sweep parked requests (re-dispatch on membership
/// changes, expire past the retry window).
fn supervisor_loop(inner: Arc<RouterInner>) {
    while !inner.closing.load(Ordering::SeqCst) {
        std::thread::sleep(inner.cfg.probe_period);
        if inner.closing.load(Ordering::SeqCst) {
            break;
        }
        // Revival: re-probe each downed shard whose previous reader has
        // fully drained; a serving probe reopens the data connection and
        // returns the shard to its (stable) ring position.
        let n = inner.shards.read().unwrap().len();
        for i in 0..n {
            let Some(shard) = inner.shard(i) else { continue };
            if shard.up.load(Ordering::SeqCst) || !shard.reader_gone.load(Ordering::SeqCst) {
                continue;
            }
            let addr = shard.addr();
            match probe_health(&addr) {
                Ok((true, ..)) => match connect_shard(&inner, i) {
                    Ok(()) => eprintln!("router: shard {i} ({addr}) revived"),
                    Err(e) => eprintln!("router: shard {i} ({addr}) revival failed: {e:#}"),
                },
                // Unreachable or not serving (all workers retired):
                // stays down, probed again next tick.
                _ => {}
            }
        }
        inner.reconcile_spares();
        sweep_parked(&inner);
    }
}

/// Re-dispatch every parked request (with its tried-set cleared, so
/// revived shards are eligible again) and expire those past the retry
/// window with an explicit error. Re-dispatch is unconditional, not
/// gated on an observed membership change: a revival can land between a
/// failed ring walk and the park, and with nothing else moving the
/// epoch that request would otherwise sleep through a healthy fleet
/// until its deadline. A fruitless re-walk per tick is cheap; missing a
/// wakeup is an avoidable client-visible error.
fn sweep_parked(inner: &Arc<RouterInner>) {
    let now = Instant::now();
    let mut expired = Vec::new();
    let mut retry = Vec::new();
    {
        let mut parked = inner.parked.lock().unwrap();
        for (id, req) in parked.drain(..) {
            if now.duration_since(req.submitted) >= inner.cfg.retry_window {
                expired.push(req);
            } else {
                retry.push((id, req));
            }
        }
    }
    for (id, mut req) in retry {
        req.tried.clear();
        inner.route(id, req);
    }
    for req in expired {
        let latency = req.submitted.elapsed();
        let _ = req.reply.send(RequestResult {
            value: 0,
            latency,
            error: Some(format!(
                "no healthy shards within the {:?} retry window (tried {:?})",
                inner.cfg.retry_window, req.tried
            )),
        });
    }
}

/// Bind the registration listener and serve `Register` frames: each
/// connection carries one announcement and gets one `Welcome` ack.
fn spawn_registration_listener(
    inner: Arc<RouterInner>,
    addr: &str,
) -> Result<(SocketAddr, JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding registration listener to {addr}"))?;
    let bound = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let handle = std::thread::spawn(move || registration_loop(inner, listener));
    Ok((bound, handle))
}

fn registration_loop(inner: Arc<RouterInner>, listener: TcpListener) {
    while !inner.closing.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_read_timeout(Some(CONTROL_TIMEOUT));
                let _ = stream.set_write_timeout(Some(CONTROL_TIMEOUT));
                match read_msg(&mut stream) {
                    Ok(Some(Msg::Register { name, addr, spare })) => {
                        let (shard, active) = inner.register(name, addr, spare);
                        let _ =
                            write_msg(&mut stream, &Msg::Welcome { shard: shard as u32, active });
                    }
                    // Malformed or non-Register traffic: drop it — the
                    // codec already refused the frame.
                    _ => {}
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            // A registrant that reset before accept completed is its
            // problem, not the listener's: discovery must keep running
            // (a dead listener would strand every future restart).
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => {
                eprintln!("router: registration listener failed, stopping: {e}");
                break;
            }
        }
    }
}

/// Probe a shard endpoint's health over a short-lived connection.
pub fn probe_health(addr: &str) -> Result<(bool, u32, u32, u32)> {
    let mut stream = control_connect(addr)?;
    write_msg(&mut stream, &Msg::HealthReq)?;
    match read_msg(&mut stream)? {
        Some(Msg::HealthReply { serving, workers, routable, retired }) => {
            Ok((serving, workers, routable, retired))
        }
        other => bail!("unexpected reply to HealthReq: {other:?}"),
    }
}

/// Fetch one shard's metrics over a short-lived connection.
pub fn fetch_metrics(addr: &str) -> Result<MetricsSnapshot> {
    let mut stream = control_connect(addr)?;
    write_msg(&mut stream, &Msg::MetricsReq)?;
    match read_msg(&mut stream)? {
        Some(Msg::MetricsReply(m)) => Ok(m),
        other => bail!("unexpected reply to MetricsReq: {other:?}"),
    }
}

/// Ask a fabric server process to stop serving (acked).
pub fn shutdown_endpoint(addr: &str) -> Result<()> {
    let mut stream = control_connect(addr)?;
    write_msg(&mut stream, &Msg::Shutdown)?;
    match read_msg(&mut stream)? {
        Some(Msg::ShutdownAck) => Ok(()),
        other => bail!("unexpected reply to Shutdown: {other:?}"),
    }
}

/// FNV-1a — stable across runs and platforms (the ring must not depend
/// on `DefaultHasher`'s randomized keys).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    hash
}

fn hash_kind(kind: FunctionKind) -> u64 {
    fnv64(kind.name().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_inner(n: usize, spares: usize) -> RouterInner {
        let mut shards: Vec<Arc<ShardState>> = (0..n)
            .map(|i| ShardState::new(format!("m{i}"), format!("127.0.0.1:{i}"), false))
            .collect();
        shards.extend(
            (0..spares)
                .map(|i| ShardState::new(format!("s{i}"), format!("127.0.0.1:9{i}"), true)),
        );
        for s in &shards {
            s.up.store(true, Ordering::SeqCst);
        }
        let inner = RouterInner {
            cfg: RouterConfig::default(),
            shards: RwLock::new(shards),
            ring: RwLock::new(Vec::new()),
            epoch: AtomicU64::new(0),
            parked: Mutex::new(Vec::new()),
            readers: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
            closing: AtomicBool::new(false),
        };
        inner.rebuild_ring();
        inner
    }

    #[test]
    fn ring_is_deterministic_and_covers_all_shards() {
        let inner = test_inner(3, 0);
        // Every walk visits each shard exactly once, and the first hop
        // is a pure function of the kind.
        for bits in 1..=32 {
            let order = inner.ring_order(hash_kind(FunctionKind::Add(bits)));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2], "walk {order:?}");
            assert_eq!(
                inner.shard_for(FunctionKind::Add(bits)),
                Some(order[0]),
                "shard_for is the walk head"
            );
        }
        // Many kinds spread over more than one shard.
        let first: Vec<usize> = (1..=32)
            .map(|bits| inner.shard_for(FunctionKind::Add(bits)).unwrap())
            .collect();
        assert!(
            first.iter().any(|&s| s != first[0]),
            "32 kinds must not all hash to one shard: {first:?}"
        );
        // Downing the preferred shard fails over to the next on the walk.
        let k = FunctionKind::Xor(8);
        let preferred = inner.shard_for(k).unwrap();
        inner.shard(preferred).unwrap().up.store(false, Ordering::SeqCst);
        let fallback = inner.shard_for(k).unwrap();
        assert_ne!(fallback, preferred);
        assert_eq!(inner.ring_order(hash_kind(k))[1], fallback);
    }

    #[test]
    fn spares_stay_out_of_the_ring_until_promoted_and_demote_cleanly() {
        let inner = test_inner(2, 1);
        let kinds: Vec<FunctionKind> =
            (1..=32).flat_map(|b| [FunctionKind::Add(b), FunctionKind::Xor(b)]).collect();
        let walks: Vec<Vec<usize>> =
            kinds.iter().map(|&k| inner.ring_order(hash_kind(k))).collect();
        for w in &walks {
            assert!(!w.contains(&2), "idle spare must stay out of the ring: {w:?}");
        }
        // Member 1 goes down: the spare is promoted and appears on
        // walks; member placement (relative order of 0 and 1) persists.
        inner.shard(1).unwrap().up.store(false, Ordering::SeqCst);
        inner.reconcile_spares();
        assert!(inner.shard(2).unwrap().promoted.load(Ordering::SeqCst));
        let during: Vec<Vec<usize>> =
            kinds.iter().map(|&k| inner.ring_order(hash_kind(k))).collect();
        assert!(during.iter().any(|w| w.contains(&2)), "promoted spare joins the ring");
        for (before, now) in walks.iter().zip(&during) {
            let filtered: Vec<usize> = now.iter().copied().filter(|&s| s != 2).collect();
            assert_eq!(&filtered, before, "members keep their relative ring order");
        }
        // Member 1 revives: the spare demotes and every walk is
        // bit-identical to never having failed.
        inner.shard(1).unwrap().up.store(true, Ordering::SeqCst);
        inner.reconcile_spares();
        assert!(!inner.shard(2).unwrap().promoted.load(Ordering::SeqCst));
        let after: Vec<Vec<usize>> =
            kinds.iter().map(|&k| inner.ring_order(hash_kind(k))).collect();
        assert_eq!(after, walks, "down/revive cycle must not move any kind");
    }

    #[test]
    fn registration_assigns_stable_slots_and_reuse_by_name() {
        let inner = test_inner(1, 0);
        let (i1, active1) = inner.register("alpha".into(), "127.0.0.1:7001".into(), false);
        assert_eq!((i1, active1), (1, true));
        let (i2, active2) = inner.register("sp".into(), "127.0.0.1:7002".into(), true);
        assert_eq!((i2, active2), (2, false), "spares start outside the ring");
        // A restarted process re-registers under its name at a new port
        // and reclaims the same slot.
        let (i3, _) = inner.register("alpha".into(), "127.0.0.1:7099".into(), false);
        assert_eq!(i3, 1);
        assert_eq!(inner.shard(1).unwrap().addr(), "127.0.0.1:7099");
        assert_eq!(inner.shards.read().unwrap().len(), 3);
    }
}
