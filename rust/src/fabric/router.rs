//! The fabric router: a client-side shard fan-out implementing
//! [`Submitter`] over N fabric servers.
//!
//! **Sharding** is FunctionKind-aware consistent hashing: each shard
//! contributes virtual nodes to a hash ring and a request's kind picks
//! the first live shard at or after its hash. Same-kind requests land
//! on the same shard, so the per-shard coordinator's dynamic batching
//! sees exactly the stream it would see in-process; losing a shard only
//! remaps the kinds it owned (classic consistent-hashing locality).
//!
//! **Failover** is health-driven: a shard is marked down when its
//! connection drops, when a write fails, or when it answers a request
//! with an all-workers-retired capacity error. In-flight requests on a
//! downed shard are re-routed to the next live shard on the ring
//! (at-least-once execution: a shard that dies after executing but
//! before replying is re-executed elsewhere — results are deterministic
//! functions, so replays are safe). Only when every shard has been
//! tried does a request resolve to an explicit error — clients never
//! hang, mirroring the in-process coordinator's contract.
//!
//! **Metrics** are fetched per shard over short-lived control
//! connections and merged ([`MetricsSnapshot::merge`]) into one fleet
//! view, so per-worker health (retirements, escalation levels) of every
//! shard is observable from one place.

use std::collections::HashMap;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::{MetricsSnapshot, NO_CAPACITY_ERROR, RequestResult, Submitter};
use crate::mmpu::FunctionKind;

use super::wire::{read_msg, write_msg, Msg};

/// Virtual nodes per shard on the hash ring.
const RING_VNODES: usize = 16;

/// Bound on control-plane connect/read/write, so a hung shard (host
/// down, blackholed traffic) cannot freeze a fleet metrics or health
/// call. The data path fails over on *closed* connections (reader EOF /
/// write error); a silently blackholed peer that keeps its connection
/// half-open is only caught by the operator or a control probe today —
/// data-path heartbeats are named multi-machine work in ROADMAP §Scale.
const CONTROL_TIMEOUT: Duration = Duration::from_secs(5);

/// Short-lived control connection with timeouts applied.
fn control_connect(addr: &str) -> Result<TcpStream> {
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr} resolves to no address"))?;
    let stream = TcpStream::connect_timeout(&sock, CONTROL_TIMEOUT)
        .with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_read_timeout(Some(CONTROL_TIMEOUT));
    let _ = stream.set_write_timeout(Some(CONTROL_TIMEOUT));
    Ok(stream)
}

/// A request in flight on some shard, retaining everything needed to
/// replay it elsewhere.
struct PendingReq {
    kind: FunctionKind,
    a: u64,
    b: u64,
    reply: Sender<RequestResult>,
    submitted: Instant,
    /// Shards already tried (failover never loops).
    tried: Vec<usize>,
}

struct ShardState {
    addr: String,
    up: AtomicBool,
    /// Write half of the data connection (`None` once down).
    writer: Mutex<Option<TcpStream>>,
    /// In-flight requests keyed by wire id.
    pending: Mutex<HashMap<u64, PendingReq>>,
}

struct RouterInner {
    shards: Vec<ShardState>,
    /// Sorted (hash, shard) ring. Keyed by shard *index* so the
    /// kind->shard map is stable across runs regardless of ephemeral
    /// ports (loopback tests rely on this determinism).
    ring: Vec<(u64, usize)>,
    next_id: AtomicU64,
    closing: AtomicBool,
}

/// The sharded remote submitter.
pub struct Router {
    inner: Arc<RouterInner>,
    readers: Vec<JoinHandle<()>>,
}

impl Router {
    /// Connect to the shard endpoints. Unreachable shards are marked
    /// down (their kinds fail over); at least one must be reachable.
    pub fn connect(addrs: &[String]) -> Result<Self> {
        ensure!(!addrs.is_empty(), "router needs at least one shard address");
        let shards: Vec<ShardState> = addrs
            .iter()
            .map(|a| ShardState {
                addr: a.clone(),
                up: AtomicBool::new(false),
                writer: Mutex::new(None),
                pending: Mutex::new(HashMap::new()),
            })
            .collect();
        let mut ring = Vec::with_capacity(addrs.len() * RING_VNODES);
        for shard in 0..addrs.len() {
            for vnode in 0..RING_VNODES {
                ring.push((fnv64(format!("shard{shard}/vnode{vnode}").as_bytes()), shard));
            }
        }
        ring.sort_unstable();
        let inner = Arc::new(RouterInner {
            shards,
            ring,
            next_id: AtomicU64::new(1),
            closing: AtomicBool::new(false),
        });
        let mut readers = Vec::new();
        for i in 0..addrs.len() {
            match inner.open_shard(i) {
                Ok(read_half) => {
                    let inner = inner.clone();
                    readers.push(std::thread::spawn(move || reader_loop(inner, i, read_half)));
                }
                Err(e) => {
                    eprintln!("router: shard {i} ({}) unreachable at connect: {e:#}", addrs[i])
                }
            }
        }
        ensure!(
            inner.shards.iter().any(|s| s.up.load(Ordering::SeqCst)),
            "no reachable shard among {addrs:?}"
        );
        Ok(Self { inner, readers })
    }

    /// The shard a kind currently routes to (None with every shard
    /// down). Exposed for tests and fleet introspection.
    pub fn shard_for(&self, kind: FunctionKind) -> Option<usize> {
        self.inner.shard_for(kind)
    }

    /// Addresses this router was built over, in shard order.
    pub fn shard_addrs(&self) -> Vec<String> {
        self.inner.shards.iter().map(|s| s.addr.clone()).collect()
    }

    /// Live shards right now.
    pub fn live_shards(&self) -> usize {
        self.inner.shards.iter().filter(|s| s.up.load(Ordering::SeqCst)).count()
    }

    pub fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        let (tx, rx) = channel();
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.inner.route(
            id,
            PendingReq { kind, a, b, reply: tx, submitted: Instant::now(), tried: Vec::new() },
        );
        rx
    }

    /// Merged fleet metrics: every shard (even one marked down for
    /// routing — its server may still answer control traffic) is probed
    /// over a short-lived connection; unreachable shards are skipped.
    /// Probes run concurrently, so a fleet of dead shards costs one
    /// `CONTROL_TIMEOUT`, not a serial sum; the merge keeps shard order.
    pub fn metrics(&self) -> MetricsSnapshot {
        let probes: Vec<_> = self
            .inner
            .shards
            .iter()
            .map(|shard| {
                let addr = shard.addr.clone();
                std::thread::spawn(move || {
                    let m = fetch_metrics(&addr);
                    (addr, m)
                })
            })
            .collect();
        let mut merged = MetricsSnapshot::default();
        for probe in probes {
            match probe.join() {
                Ok((_, Ok(m))) => merged.merge(&m),
                Ok((addr, Err(e))) => {
                    eprintln!("router: metrics from {addr} unavailable: {e:#}")
                }
                Err(_) => {}
            }
        }
        merged
    }

    pub fn is_serving(&self) -> bool {
        self.live_shards() > 0
    }

    /// Close every shard connection and join the reader threads.
    /// In-flight requests resolve with explicit shutdown errors.
    pub fn shutdown(mut self) {
        self.inner.closing.store(true, Ordering::SeqCst);
        for i in 0..self.inner.shards.len() {
            self.inner.mark_down(i);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Submitter for Router {
    fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        Router::submit(self, kind, a, b)
    }

    fn metrics(&self) -> MetricsSnapshot {
        Router::metrics(self)
    }

    fn is_serving(&self) -> bool {
        Router::is_serving(self)
    }
}

impl RouterInner {
    /// Open the data connection for shard `i`; returns the read half
    /// (the write half is stored) and marks the shard up.
    fn open_shard(&self, i: usize) -> Result<TcpStream> {
        let shard = &self.shards[i];
        let stream = TcpStream::connect(shard.addr.as_str())
            .with_context(|| format!("connecting to shard {}", shard.addr))?;
        let _ = stream.set_nodelay(true);
        let write_half = stream.try_clone()?;
        *shard.writer.lock().unwrap() = Some(write_half);
        shard.up.store(true, Ordering::SeqCst);
        Ok(stream)
    }

    /// Walk shard indices in ring order starting at `hash` (vnodes
    /// deduplicated), yielding each shard once.
    fn ring_order(&self, hash: u64) -> Vec<usize> {
        let start = self.ring.partition_point(|&(h, _)| h < hash);
        let mut seen = vec![false; self.shards.len()];
        let mut order = Vec::with_capacity(self.shards.len());
        for k in 0..self.ring.len() {
            let shard = self.ring[(start + k) % self.ring.len()].1;
            if !seen[shard] {
                seen[shard] = true;
                order.push(shard);
            }
        }
        order
    }

    fn shard_for(&self, kind: FunctionKind) -> Option<usize> {
        self.ring_order(hash_kind(kind))
            .into_iter()
            .find(|&s| self.shards[s].up.load(Ordering::SeqCst))
    }

    /// Dispatch (or re-dispatch) a request to the first live shard on
    /// its kind's ring walk that hasn't been tried yet; with none left,
    /// resolve it with an explicit error.
    fn route(&self, id: u64, mut req: PendingReq) {
        for shard_idx in self.ring_order(hash_kind(req.kind)) {
            if req.tried.contains(&shard_idx) {
                continue;
            }
            let shard = &self.shards[shard_idx];
            if !shard.up.load(Ordering::SeqCst) {
                continue;
            }
            req.tried.push(shard_idx);
            let msg = Msg::Submit { id, kind: req.kind, a: req.a, b: req.b };
            // Register before writing so the reader can match a fast
            // reply; reclaim on write failure.
            shard.pending.lock().unwrap().insert(id, req);
            let wrote = match shard.writer.lock().unwrap().as_mut() {
                Some(stream) => write_msg(stream, &msg).is_ok(),
                None => false,
            };
            if wrote {
                return;
            }
            self.mark_down(shard_idx);
            req = match shard.pending.lock().unwrap().remove(&id) {
                Some(r) => r,
                // The reader drained it first and is re-routing it.
                None => return,
            };
        }
        let latency = req.submitted.elapsed();
        let _ = req.reply.send(RequestResult {
            value: 0,
            latency,
            error: Some(format!("no healthy shards (tried {:?})", req.tried)),
        });
    }

    /// Take a shard out of routing and unblock its reader.
    fn mark_down(&self, i: usize) {
        let was_up = self.shards[i].up.swap(false, Ordering::SeqCst);
        if was_up && !self.closing.load(Ordering::SeqCst) {
            eprintln!("router: shard {i} ({}) marked down", self.shards[i].addr);
        }
        if let Some(w) = self.shards[i].writer.lock().unwrap().take() {
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Per-shard reader: matches `Result` frames to pending requests, turns
/// capacity errors into failovers, and on disconnect re-routes whatever
/// was still in flight.
fn reader_loop(inner: Arc<RouterInner>, shard_idx: usize, mut read_half: TcpStream) {
    loop {
        match read_msg(&mut read_half) {
            Ok(Some(Msg::Result { id, value, latency_us: _, error })) => {
                let req = inner.shards[shard_idx].pending.lock().unwrap().remove(&id);
                let Some(req) = req else { continue };
                // An all-workers-retired shard answers every request
                // with the coordinator's capacity error: mark it down
                // and fail the request over instead of delivering it.
                let capacity_error =
                    error.as_deref().is_some_and(|e| e.contains(NO_CAPACITY_ERROR));
                if capacity_error && !inner.closing.load(Ordering::SeqCst) {
                    inner.mark_down(shard_idx);
                    inner.route(id, req);
                    continue;
                }
                let latency = req.submitted.elapsed();
                let _ = req.reply.send(RequestResult { value, latency, error });
            }
            // Control replies ride dedicated connections; anything else
            // here is a protocol violation — drop the connection.
            Ok(Some(_)) => break,
            Ok(None) | Err(_) => break,
        }
    }
    inner.mark_down(shard_idx);
    // Fail over (or, at router shutdown, resolve) the in-flight tail.
    let drained: Vec<(u64, PendingReq)> =
        inner.shards[shard_idx].pending.lock().unwrap().drain().collect();
    let closing = inner.closing.load(Ordering::SeqCst);
    if !drained.is_empty() && !closing {
        eprintln!(
            "router: shard {shard_idx} disconnected with {} in flight; rerouting",
            drained.len()
        );
    }
    for (id, req) in drained {
        if closing {
            let latency = req.submitted.elapsed();
            let _ = req.reply.send(RequestResult {
                value: 0,
                latency,
                error: Some("router shutting down".to_string()),
            });
        } else {
            inner.route(id, req);
        }
    }
}

/// Probe a shard endpoint's health over a short-lived connection.
pub fn probe_health(addr: &str) -> Result<(bool, u32, u32, u32)> {
    let mut stream = control_connect(addr)?;
    write_msg(&mut stream, &Msg::HealthReq)?;
    match read_msg(&mut stream)? {
        Some(Msg::HealthReply { serving, workers, routable, retired }) => {
            Ok((serving, workers, routable, retired))
        }
        other => bail!("unexpected reply to HealthReq: {other:?}"),
    }
}

/// Fetch one shard's metrics over a short-lived connection.
pub fn fetch_metrics(addr: &str) -> Result<MetricsSnapshot> {
    let mut stream = control_connect(addr)?;
    write_msg(&mut stream, &Msg::MetricsReq)?;
    match read_msg(&mut stream)? {
        Some(Msg::MetricsReply(m)) => Ok(m),
        other => bail!("unexpected reply to MetricsReq: {other:?}"),
    }
}

/// Ask a fabric server process to stop serving (acked).
pub fn shutdown_endpoint(addr: &str) -> Result<()> {
    let mut stream = control_connect(addr)?;
    write_msg(&mut stream, &Msg::Shutdown)?;
    match read_msg(&mut stream)? {
        Some(Msg::ShutdownAck) => Ok(()),
        other => bail!("unexpected reply to Shutdown: {other:?}"),
    }
}

/// FNV-1a — stable across runs and platforms (the ring must not depend
/// on `DefaultHasher`'s randomized keys).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    hash
}

fn hash_kind(kind: FunctionKind) -> u64 {
    fnv64(kind.name().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_deterministic_and_covers_all_shards() {
        let mut ring = Vec::new();
        for shard in 0..3usize {
            for vnode in 0..RING_VNODES {
                ring.push((fnv64(format!("shard{shard}/vnode{vnode}").as_bytes()), shard));
            }
        }
        ring.sort_unstable();
        let inner = RouterInner {
            shards: (0..3)
                .map(|i| ShardState {
                    addr: format!("127.0.0.1:{i}"),
                    up: AtomicBool::new(true),
                    writer: Mutex::new(None),
                    pending: Mutex::new(HashMap::new()),
                })
                .collect(),
            ring,
            next_id: AtomicU64::new(1),
            closing: AtomicBool::new(false),
        };
        // Every walk visits each shard exactly once, and the first hop
        // is a pure function of the kind.
        for bits in 1..=32 {
            let order = inner.ring_order(hash_kind(FunctionKind::Add(bits)));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2], "walk {order:?}");
            assert_eq!(
                inner.shard_for(FunctionKind::Add(bits)),
                Some(order[0]),
                "shard_for is the walk head"
            );
        }
        // Many kinds spread over more than one shard.
        let first: Vec<usize> = (1..=32)
            .map(|bits| inner.shard_for(FunctionKind::Add(bits)).unwrap())
            .collect();
        assert!(
            first.iter().any(|&s| s != first[0]),
            "32 kinds must not all hash to one shard: {first:?}"
        );
        // Downing the preferred shard fails over to the next on the walk.
        let k = FunctionKind::Xor(8);
        let preferred = inner.shard_for(k).unwrap();
        inner.shards[preferred].up.store(false, Ordering::SeqCst);
        let fallback = inner.shard_for(k).unwrap();
        assert_ne!(fallback, preferred);
        assert_eq!(inner.ring_order(hash_kind(k))[1], fallback);
    }
}
