//! Event-driven fabric data plane (§Scale): a hand-rolled epoll
//! readiness loop over nonblocking sockets.
//!
//! The threads plane (one blocking reader/writer thread pair per
//! connection) is simple and stays as the bit-exact reference, but it
//! saturates on *connection count* long before the shards saturate on
//! compute: every idle connection pins two stacks, and every reply
//! write can block a thread. This module multiplexes all of a server's
//! data connections onto **one** thread:
//!
//! * readiness via raw `epoll` syscalls (declared here — the offline
//!   vendor set has no `libc` crate, but the symbols live in the same
//!   C library every Linux `std` binary already links);
//! * per-connection read buffers feeding the incremental
//!   [`FrameDecoder`], which preserves the v7 codec and the PSK sealed
//!   framing byte-for-byte (same length validation, same marker
//!   rejection, same implicit seal counters);
//! * per-connection write queues flushed with **vectored writes**
//!   (up to [`WRITE_BATCH`] frames per `writev` — the coalescing
//!   rule), so a burst of ready replies costs one syscall, not one
//!   per frame;
//! * **bounded backpressure**: a peer that stops draining its replies
//!   accumulates at most [`MAX_CONN_BACKLOG`] queued bytes and is then
//!   disconnected — the byte-bound analogue of the threads plane's
//!   bounded reply write timeout;
//! * submit pipelining falls out naturally: every decodable frame is
//!   dispatched to the coordinator immediately, replies resolve out of
//!   band and are still written in strict FIFO per connection (the
//!   queue head blocks the queue, exactly like the threads writer).
//!
//! PSK handshakes stay on short-lived helper threads (bounded by
//! [`HANDSHAKE_TIMEOUT`]): the handshake is a 3-message blocking
//! exchange whose bytes must not change, and running it off-loop keeps
//! a stalling peer from freezing every other connection. The reactor
//! adopts the socket once the session keys exist.
//!
//! Everything here is Linux-only ([`supported`]); on other platforms
//! the server falls back to the threads plane with a loud warning.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::Coordinator;

use super::auth::{
    encode_frame, server_handshake, Channel, FrameDecoder, Psk, Seal, FRAME_DEADLINE,
    HANDSHAKE_TIMEOUT,
};
use super::server::{
    dispatch_msg, dropped_result_msg, result_msg, transient_accept_error, Dispatch, Reply,
    ACCEPT_BACKOFF_MAX, ACCEPT_BACKOFF_START,
};
use super::wire::Msg;

/// Which transport carries fabric data connections (§Scale).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DataPlane {
    /// One blocking reader/writer thread pair per connection — the
    /// bit-exact reference plane.
    #[default]
    Threads,
    /// One readiness loop (Linux epoll) multiplexing every connection
    /// over nonblocking sockets.
    Epoll,
}

impl DataPlane {
    /// Parse a `--data-plane` value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threads" => Ok(Self::Threads),
            "epoll" => Ok(Self::Epoll),
            other => anyhow::bail!("unknown data plane {other:?} (expected `epoll` or `threads`)"),
        }
    }

    /// Resolve the `REMUS_DATA_PLANE` environment override, falling
    /// back to `default` when unset. This is how the integration and
    /// chaos suites re-run their exact scenarios under the reactor:
    /// `ServeOptions::default()` and `RouterConfig::default()` both
    /// call this, so every test fleet follows the variable.
    pub fn from_env_or(default: Self) -> Self {
        match std::env::var("REMUS_DATA_PLANE") {
            Ok(v) => match Self::parse(&v) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("warning: ignoring REMUS_DATA_PLANE: {e}");
                    default
                }
            },
            Err(_) => default,
        }
    }
}

impl std::fmt::Display for DataPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Threads => "threads",
            Self::Epoll => "epoll",
        })
    }
}

/// True when the epoll plane can run on this platform.
pub fn supported() -> bool {
    cfg!(target_os = "linux")
}

/// A slow consumer may owe at most this many undelivered reply bytes
/// before its connection is closed — the reactor's backpressure bound.
pub const MAX_CONN_BACKLOG: usize = 4 << 20;

/// Most frames coalesced into one vectored write.
pub(crate) const WRITE_BATCH: usize = 64;

// Readiness flags (bits of `epoll_event.events`). Values are part of
// the Linux ABI.
pub(crate) const EPOLLIN: u32 = 0x1;
pub(crate) const EPOLLOUT: u32 = 0x4;
pub(crate) const EPOLLERR: u32 = 0x8;
pub(crate) const EPOLLHUP: u32 = 0x10;
pub(crate) const EPOLLRDHUP: u32 = 0x2000;

/// Any readiness bit that means "the read side has news" — data,
/// peer half-close, or an error the next read will surface.
pub(crate) const EPOLL_READ_EVENTS: u32 = EPOLLIN | EPOLLRDHUP | EPOLLERR | EPOLLHUP;

const MAX_EVENTS: usize = 128;

#[cfg(target_os = "linux")]
mod sys {
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0x80000;

    /// `struct epoll_event`. Packed on x86-64 (and only there) to
    /// match the kernel/glibc ABI exactly; fields are only ever read
    /// by value, never by reference.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    // No `libc` crate in the offline vendor set, but these symbols are
    // in the C library every Linux std binary links anyway.
    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

/// Minimal safe wrapper over an epoll instance. Tokens are caller-
/// chosen `u64`s handed back verbatim with each readiness event.
pub(crate) struct Epoll {
    #[cfg(target_os = "linux")]
    fd: RawFd,
}

#[cfg(target_os = "linux")]
impl Epoll {
    pub(crate) fn new() -> Result<Self> {
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error()).context("epoll_create1");
        }
        Ok(Self { fd })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> Result<()> {
        let mut ev = sys::EpollEvent { events, data: token };
        let rc = unsafe { sys::epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(std::io::Error::last_os_error()).context("epoll_ctl");
        }
        Ok(())
    }

    pub(crate) fn add(&self, fd: RawFd, events: u32, token: u64) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, events, token)
    }

    pub(crate) fn modify(&self, fd: RawFd, events: u32, token: u64) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, events, token)
    }

    pub(crate) fn del(&self, fd: RawFd) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait up to `timeout` for readiness, appending `(token, events)`
    /// pairs to `out` (cleared first). `EINTR` is an empty wake-up,
    /// never an error.
    pub(crate) fn wait(&self, timeout: Duration, out: &mut Vec<(u64, u32)>) {
        out.clear();
        let mut buf = [sys::EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        let n = unsafe { sys::epoll_wait(self.fd, buf.as_mut_ptr(), MAX_EVENTS as i32, ms) };
        for ev in buf.iter().take(n.max(0) as usize) {
            out.push((ev.data, ev.events));
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.fd);
        }
    }
}

#[cfg(not(target_os = "linux"))]
impl Epoll {
    pub(crate) fn new() -> Result<Self> {
        anyhow::bail!("the epoll data plane is only available on Linux")
    }

    pub(crate) fn add(&self, _fd: RawFd, _events: u32, _token: u64) -> Result<()> {
        unreachable!("Epoll cannot be constructed off-Linux")
    }

    pub(crate) fn modify(&self, _fd: RawFd, _events: u32, _token: u64) -> Result<()> {
        unreachable!("Epoll cannot be constructed off-Linux")
    }

    pub(crate) fn del(&self, _fd: RawFd) -> Result<()> {
        unreachable!("Epoll cannot be constructed off-Linux")
    }

    pub(crate) fn wait(&self, _timeout: Duration, _out: &mut Vec<(u64, u32)>) {
        unreachable!("Epoll cannot be constructed off-Linux")
    }
}

/// One vectored write over up to [`WRITE_BATCH`] queued frames,
/// starting `front` bytes into the first — the coalescing rule shared
/// by the server reactor and [`ConnTx`].
fn write_queued(stream: &TcpStream, out: &VecDeque<Vec<u8>>, front: usize) -> std::io::Result<usize> {
    let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(out.len().min(WRITE_BATCH));
    let mut it = out.iter();
    if let Some(first) = it.next() {
        slices.push(IoSlice::new(&first[front..]));
    }
    for frame in it.take(WRITE_BATCH - 1) {
        slices.push(IoSlice::new(frame));
    }
    let mut w = stream;
    w.write_vectored(&slices)
}

/// Drop `n` freshly written bytes off the front of the queue; returns
/// the new offset into the (possibly new) first frame.
fn advance_queued(out: &mut VecDeque<Vec<u8>>, mut front: usize, mut n: usize) -> usize {
    while n > 0 {
        let rem = out[0].len() - front;
        if n >= rem {
            n -= rem;
            front = 0;
            out.pop_front();
        } else {
            front += n;
            n = 0;
        }
    }
    front
}

// ---------------------------------------------------------------------------
// Client-side transmit handle (the router's epoll-mode shard writer)
// ---------------------------------------------------------------------------

/// Transmit handle for a reactor-managed *outbound* connection (the
/// router's data connection to a shard). `send` seals and enqueues
/// under a lock — preserving the seal's implicit counter order — then
/// opportunistically flushes without blocking; whatever `WouldBlock`
/// leaves behind is drained by the owning reactor's tick (and bounded
/// by [`MAX_CONN_BACKLOG`], after which the connection is condemned).
#[derive(Clone)]
pub(crate) struct ConnTx {
    inner: Arc<Mutex<TxState>>,
}

struct TxState {
    stream: TcpStream,
    seal: Option<Seal>,
    out: VecDeque<Vec<u8>>,
    front: usize,
    bytes: usize,
    closed: bool,
}

impl ConnTx {
    /// `stream` must already be nonblocking; `seal` is the established
    /// session's transmit half (counter state preserved).
    pub(crate) fn new(stream: TcpStream, seal: Option<Seal>) -> Self {
        Self {
            inner: Arc::new(Mutex::new(TxState {
                stream,
                seal,
                out: VecDeque::new(),
                front: 0,
                bytes: 0,
                closed: false,
            })),
        }
    }

    /// Seal + enqueue + best-effort flush. An error condemns the
    /// connection (the socket is shut down so the reactor's read side
    /// notices and runs the normal failover path).
    pub(crate) fn send(&self, msg: &Msg) -> Result<()> {
        let mut st = self.inner.lock().unwrap();
        if st.closed {
            anyhow::bail!("connection closed");
        }
        let frame = encode_frame(msg, &mut st.seal)?;
        if st.bytes + frame.len() > MAX_CONN_BACKLOG {
            st.close();
            anyhow::bail!(
                "shard connection exceeded its {MAX_CONN_BACKLOG} byte write backlog \
                 (closing slow consumer)"
            );
        }
        st.bytes += frame.len();
        st.out.push_back(frame);
        st.flush()
    }

    /// Drain whatever the socket will take right now (reactor tick).
    pub(crate) fn flush(&self) -> Result<()> {
        self.inner.lock().unwrap().flush()
    }

    /// Condemn the connection (e.g. on router shutdown).
    pub(crate) fn shutdown(&self) {
        self.inner.lock().unwrap().close();
    }
}

impl TxState {
    fn close(&mut self) {
        self.closed = true;
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    fn flush(&mut self) -> Result<()> {
        loop {
            if self.out.is_empty() {
                return Ok(());
            }
            match write_queued(&self.stream, &self.out, self.front) {
                Ok(0) => {
                    self.close();
                    anyhow::bail!("connection closed while flushing");
                }
                Ok(n) => {
                    self.bytes -= n;
                    self.front = advance_queued(&mut self.out, self.front, n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    self.close();
                    return Err(e).context("shard connection write");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Server reactor
// ---------------------------------------------------------------------------

/// Reactor token reserved for the listener.
const LISTENER_TOKEN: u64 = u64::MAX;

/// Tick when at least one pending coordinator reply is unresolved: poll
/// the reply channels at millisecond granularity.
const TICK_BUSY: Duration = Duration::from_millis(1);
/// Idle tick: just often enough to observe the stop flag and finished
/// handshakes promptly.
const TICK_IDLE: Duration = Duration::from_millis(10);

/// Bounded best-effort flush window after the stop flag flips, so a
/// remote `Shutdown` still gets its `ShutdownAck` delivered.
const DRAIN_DEADLINE: Duration = Duration::from_millis(500);

/// A completed PSK handshake handing its connection to the reactor.
struct HsDone {
    conn_id: u64,
    stream: TcpStream,
    chan: Channel,
}

/// Per-connection reactor state. Mirrors the threads plane exactly:
/// `replies` is the FIFO the writer thread would have walked, `out` is
/// the bytes it would have written.
struct Conn {
    stream: TcpStream,
    dec: FrameDecoder,
    tx_seal: Option<Seal>,
    replies: VecDeque<Reply>,
    out: VecDeque<Vec<u8>>,
    out_front: usize,
    out_bytes: usize,
    /// Armed while a partial frame is buffered ([`FRAME_DEADLINE`]).
    frame_deadline: Option<Instant>,
    /// Peer closed its write side: stop reading, drain what we owe.
    peer_eof: bool,
    /// Stop reading (decode error / violation / shutdown ack queued);
    /// drain `replies` + `out`, then close — the same drain the
    /// threads plane's writer performs after its reader exits.
    closing: bool,
    /// Close immediately, no drain (write failure or backpressure).
    dead: bool,
    /// Readiness bits currently registered with the epoll instance.
    interest: u32,
    token: u64,
}

impl Conn {
    fn finished(&self) -> bool {
        self.dead
            || ((self.peer_eof || self.closing) && self.replies.is_empty() && self.out.is_empty())
    }

    fn desired_interest(&self) -> u32 {
        if self.dead {
            return 0;
        }
        let mut ev = 0;
        if !self.closing && !self.peer_eof {
            ev |= EPOLLIN | EPOLLRDHUP;
        }
        if !self.out.is_empty() {
            ev |= EPOLLOUT;
        }
        ev
    }
}

/// The epoll data plane's counterpart of `server::accept_loop` +
/// `conn_loop` + `writer_loop`: one thread, every connection. Spawned
/// by `FabricServer::start_with_options` when `--data-plane epoll`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_reactor(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    psk: Arc<Option<Psk>>,
    auth_rejects: Arc<AtomicU64>,
    boot_epoch: u64,
) {
    let ep = match Epoll::new() {
        Ok(ep) => ep,
        Err(e) => {
            eprintln!("fabric server: FATAL: cannot start epoll reactor, stopping: {e:#}");
            stop.store(true, Ordering::SeqCst);
            return;
        }
    };
    if let Err(e) = ep.add(listener.as_raw_fd(), EPOLLIN, LISTENER_TOKEN) {
        eprintln!("fabric server: FATAL: cannot watch listener, stopping: {e:#}");
        stop.store(true, Ordering::SeqCst);
        return;
    }
    let (hs_tx, hs_rx) = channel::<HsDone>();
    let mut table: HashMap<u64, Conn> = HashMap::new();
    let mut events: Vec<(u64, u32)> = Vec::new();
    let mut next_conn_id = 0u64;
    let mut accept_backoff = ACCEPT_BACKOFF_START;
    // While Some, the listener is deregistered (transient accept error
    // backoff) and re-armed when the pause expires.
    let mut accept_paused_until: Option<Instant> = None;

    while !stop.load(Ordering::SeqCst) {
        // Re-arm the listener once an accept-error backoff expires.
        if let Some(until) = accept_paused_until {
            if Instant::now() >= until {
                accept_paused_until = None;
                if ep.add(listener.as_raw_fd(), EPOLLIN, LISTENER_TOKEN).is_err() {
                    eprintln!("fabric server: FATAL: cannot re-arm listener, stopping");
                    stop.store(true, Ordering::SeqCst);
                    break;
                }
            }
        }
        // Adopt connections whose PSK handshake just completed.
        while let Ok(done) = hs_rx.try_recv() {
            adopt(&ep, &mut table, &conns, done.conn_id, done.stream, Some(done.chan));
        }
        // Resolve ready coordinator replies (FIFO per connection),
        // flush, retire finished connections.
        let mut waiting = false;
        let now = Instant::now();
        let finished: Vec<u64> = {
            let mut finished = Vec::new();
            for (&id, conn) in table.iter_mut() {
                if let Some(deadline) = conn.frame_deadline {
                    if now >= deadline && !conn.closing {
                        // Same slowloris semantics as the blocking
                        // reader's FRAME_DEADLINE error.
                        if conn.dec.is_sealed() {
                            auth_rejects.fetch_add(1, Ordering::SeqCst);
                        }
                        conn.closing = true;
                    }
                }
                waiting |= drain_replies(conn);
                flush_conn(conn);
                update_interest(&ep, conn);
                if conn.finished() {
                    finished.push(id);
                }
            }
            finished
        };
        for id in finished {
            if let Some(conn) = table.remove(&id) {
                retire(&ep, &conns, id, conn);
            }
        }
        // Wait for readiness; poll faster while replies are pending.
        let tick = if waiting { TICK_BUSY } else { TICK_IDLE };
        ep.wait(tick, &mut events);
        for &(token, evs) in &events {
            if token == LISTENER_TOKEN {
                if accept_paused_until.is_some() {
                    continue;
                }
                accept_burst(
                    &ep,
                    &listener,
                    &mut table,
                    &conns,
                    &conn_handles,
                    &psk,
                    &auth_rejects,
                    &stop,
                    &hs_tx,
                    &mut next_conn_id,
                    &mut accept_backoff,
                    &mut accept_paused_until,
                );
                continue;
            }
            let Some(conn) = table.get_mut(&token) else {
                continue;
            };
            if evs & EPOLL_READ_EVENTS != 0 && !conn.closing && !conn.peer_eof {
                read_ready(conn, &coord, &stop, &auth_rejects, boot_epoch);
            }
            if evs & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0 {
                flush_conn(conn);
            }
            update_interest(&ep, conn);
        }
    }

    // Stop flag flipped (locally, or by a Shutdown frame we just
    // queued the ack for): give pending replies a bounded window to
    // resolve and flush, so remote shutdowns observe their ack.
    let deadline = Instant::now() + DRAIN_DEADLINE;
    loop {
        let mut outstanding = false;
        let finished: Vec<u64> = {
            let mut finished = Vec::new();
            for (&id, conn) in table.iter_mut() {
                drain_replies(conn);
                flush_conn(conn);
                if conn.finished() {
                    finished.push(id);
                } else if !conn.replies.is_empty() || !conn.out.is_empty() {
                    outstanding = true;
                }
            }
            finished
        };
        for id in finished {
            if let Some(conn) = table.remove(&id) {
                retire(&ep, &conns, id, conn);
            }
        }
        if !outstanding || Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(TICK_BUSY);
    }
    for (id, conn) in table.drain() {
        retire(&ep, &conns, id, conn);
    }
}

/// Register an established (plaintext or freshly handshaken)
/// connection with the loop.
fn adopt(
    ep: &Epoll,
    table: &mut HashMap<u64, Conn>,
    conns: &Mutex<HashMap<u64, TcpStream>>,
    conn_id: u64,
    stream: TcpStream,
    chan: Option<Channel>,
) {
    if stream.set_nonblocking(true).is_err() {
        // Socket already dead: drop it and its shutdown-registry dup.
        conns.lock().unwrap().remove(&conn_id);
        return;
    }
    let (tx_seal, rx_seal) = match chan {
        Some(c) => (Some(c.tx), Some(c.rx)),
        None => (None, None),
    };
    let mut conn = Conn {
        stream,
        dec: FrameDecoder::new(rx_seal),
        tx_seal,
        replies: VecDeque::new(),
        out: VecDeque::new(),
        out_front: 0,
        out_bytes: 0,
        frame_deadline: None,
        peer_eof: false,
        closing: false,
        dead: false,
        interest: 0,
        token: conn_id,
    };
    update_interest(ep, &mut conn);
    table.insert(conn_id, conn);
}

/// Deregister + drop a connection. The explicit `EPOLL_CTL_DEL`
/// matters: the shutdown registry holds a dup of this socket, so
/// closing our fd alone would leave a stale interest entry behind.
fn retire(ep: &Epoll, conns: &Mutex<HashMap<u64, TcpStream>>, id: u64, conn: Conn) {
    if conn.interest != 0 {
        let _ = ep.del(conn.stream.as_raw_fd());
    }
    conns.lock().unwrap().remove(&id);
}

fn update_interest(ep: &Epoll, conn: &mut Conn) {
    let want = conn.desired_interest();
    if want == conn.interest {
        return;
    }
    let fd = conn.stream.as_raw_fd();
    let outcome = if conn.interest == 0 {
        ep.add(fd, want, conn.token)
    } else if want == 0 {
        ep.del(fd)
    } else {
        ep.modify(fd, want, conn.token)
    };
    if outcome.is_ok() {
        conn.interest = want;
    } else {
        conn.dead = true;
    }
}

/// Accept everything currently queued on the listener. Transient
/// errors pause accepting with bounded backoff (the listener is taken
/// off the loop so a level-triggered event can't spin); persistent
/// errors stop the server loudly, exactly like the threads plane.
#[allow(clippy::too_many_arguments)]
fn accept_burst(
    ep: &Epoll,
    listener: &TcpListener,
    table: &mut HashMap<u64, Conn>,
    conns: &Arc<Mutex<HashMap<u64, TcpStream>>>,
    conn_handles: &Mutex<Vec<JoinHandle<()>>>,
    psk: &Arc<Option<Psk>>,
    auth_rejects: &Arc<AtomicU64>,
    stop: &Arc<AtomicBool>,
    hs_tx: &Sender<HsDone>,
    next_conn_id: &mut u64,
    backoff: &mut Duration,
    paused_until: &mut Option<Instant>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                *backoff = ACCEPT_BACKOFF_START;
                let _ = stream.set_nodelay(true);
                let conn_id = *next_conn_id;
                *next_conn_id += 1;
                if let Ok(clone) = stream.try_clone() {
                    conns.lock().unwrap().insert(conn_id, clone);
                }
                match (**psk).as_ref() {
                    None => adopt(ep, table, conns, conn_id, stream, None),
                    Some(_) => {
                        // The 3-message blocking handshake runs on a
                        // short-lived thread (bounded by
                        // HANDSHAKE_TIMEOUT both ways), so a stalling
                        // peer can't freeze the loop; the reactor
                        // adopts the socket once keys exist.
                        let _ = stream.set_nonblocking(false);
                        let psk = psk.clone();
                        let auth_rejects = auth_rejects.clone();
                        let conns = conns.clone();
                        let hs_tx = hs_tx.clone();
                        let handle = std::thread::spawn(move || {
                            let mut stream = stream;
                            let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
                            let p = (*psk).as_ref().expect("psk checked by caller");
                            match server_handshake(&mut stream, p) {
                                Ok(chan) => {
                                    if hs_tx.send(HsDone { conn_id, stream, chan }).is_err() {
                                        // Reactor already gone: drop the
                                        // socket and its registry entry.
                                        conns.lock().unwrap().remove(&conn_id);
                                    }
                                }
                                Err(e) => {
                                    auth_rejects.fetch_add(1, Ordering::SeqCst);
                                    eprintln!("fabric server: rejected peer: {e:#}");
                                    conns.lock().unwrap().remove(&conn_id);
                                }
                            }
                        });
                        let mut handles = conn_handles.lock().unwrap();
                        handles.retain(|h| !h.is_finished());
                        handles.push(handle);
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if transient_accept_error(&e) => {
                eprintln!(
                    "fabric server: transient accept error (retrying in {:?}): {e}",
                    *backoff
                );
                let _ = ep.del(listener.as_raw_fd());
                *paused_until = Some(Instant::now() + *backoff);
                *backoff = (*backoff * 2).min(ACCEPT_BACKOFF_MAX);
                return;
            }
            Err(e) => {
                eprintln!("fabric server: FATAL: accept failed, stopping listener: {e}");
                stop.store(true, Ordering::SeqCst);
                return;
            }
        }
    }
}

/// Drain the socket into the decoder and dispatch every complete
/// message. Mirrors `conn_loop`'s read-side behaviour, including which
/// failures count as auth rejects on a sealed connection.
fn read_ready(
    conn: &mut Conn,
    coord: &Coordinator,
    stop: &AtomicBool,
    auth_rejects: &AtomicU64,
    boot_epoch: u64,
) {
    let mut buf = [0u8; 16 * 1024];
    'read: loop {
        let n = {
            let mut r = &conn.stream;
            match r.read(&mut buf) {
                Ok(0) => {
                    conn.peer_eof = true;
                    break 'read;
                }
                Ok(n) => n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break 'read,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    // Same accounting as the blocking reader: any read
                    // error on a sealed connection is an auth reject.
                    if conn.dec.is_sealed() {
                        auth_rejects.fetch_add(1, Ordering::SeqCst);
                    }
                    conn.closing = true;
                    break 'read;
                }
            }
        };
        conn.dec.push(&buf[..n]);
        loop {
            match conn.dec.try_next() {
                Ok(Some(msg)) => match dispatch_msg(msg, coord, auth_rejects, boot_epoch) {
                    Dispatch::Reply(reply) => conn.replies.push_back(reply),
                    Dispatch::Shutdown(ack) => {
                        conn.replies.push_back(ack);
                        stop.store(true, Ordering::SeqCst);
                        conn.closing = true;
                    }
                    Dispatch::Violation => conn.closing = true,
                },
                Ok(None) => break,
                Err(_) => {
                    // Tampered/replayed/malformed frame: drop the
                    // connection (after draining what we owe), count
                    // the reject when sealed.
                    if conn.dec.is_sealed() {
                        auth_rejects.fetch_add(1, Ordering::SeqCst);
                    }
                    conn.closing = true;
                }
            }
            if conn.closing {
                break 'read;
            }
        }
    }
    // Slowloris accounting: arm the frame deadline while a partial
    // frame is buffered, clear it at every frame boundary.
    conn.frame_deadline = if conn.dec.mid_frame() && !conn.closing && !conn.peer_eof {
        Some(conn.frame_deadline.unwrap_or_else(|| Instant::now() + FRAME_DEADLINE))
    } else {
        None
    };
}

/// Walk the FIFO reply queue, encoding every reply that has resolved.
/// Returns true when the queue head is an unresolved coordinator
/// reply (the reactor should poll soon).
fn drain_replies(conn: &mut Conn) -> bool {
    if conn.dead {
        return false;
    }
    while let Some(reply) = conn.replies.pop_front() {
        let msg = match reply {
            Reply::Now(m) => m,
            Reply::Pending(id, rx) => match rx.try_recv() {
                Ok(r) => result_msg(id, r),
                Err(TryRecvError::Empty) => {
                    // FIFO: the head blocks the queue, exactly like
                    // the threads plane's writer.
                    conn.replies.push_front(Reply::Pending(id, rx));
                    return true;
                }
                Err(TryRecvError::Disconnected) => dropped_result_msg(id),
            },
        };
        let frame = match encode_frame(&msg, &mut conn.tx_seal) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("fabric server: dropping connection (encode failed): {e:#}");
                conn.dead = true;
                return false;
            }
        };
        if conn.out_bytes + frame.len() > MAX_CONN_BACKLOG {
            eprintln!(
                "fabric server: closing slow consumer (> {MAX_CONN_BACKLOG} bytes of \
                 undelivered replies)"
            );
            conn.dead = true;
            return false;
        }
        conn.out_bytes += frame.len();
        conn.out.push_back(frame);
    }
    false
}

/// Write as much of the out-queue as the socket will take.
fn flush_conn(conn: &mut Conn) {
    if conn.dead {
        return;
    }
    loop {
        if conn.out.is_empty() {
            return;
        }
        match write_queued(&conn.stream, &conn.out, conn.out_front) {
            Ok(0) => {
                conn.dead = true;
                return;
            }
            Ok(n) => {
                conn.out_bytes -= n;
                conn.out_front = advance_queued(&mut conn.out, conn.out_front, n);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                // Peer gone mid-write: same as the threads writer
                // erroring out — close without draining the rest.
                conn.dead = true;
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_plane_parses_and_displays() {
        assert_eq!(DataPlane::parse("epoll").unwrap(), DataPlane::Epoll);
        assert_eq!(DataPlane::parse("threads").unwrap(), DataPlane::Threads);
        assert!(DataPlane::parse("io_uring").is_err());
        assert_eq!(DataPlane::Epoll.to_string(), "epoll");
        assert_eq!(DataPlane::default(), DataPlane::Threads);
    }

    #[test]
    fn advance_queued_walks_frame_boundaries() {
        let mut out: VecDeque<Vec<u8>> = VecDeque::new();
        out.push_back(vec![0u8; 4]);
        out.push_back(vec![0u8; 6]);
        out.push_back(vec![0u8; 2]);
        // Partial first frame.
        let front = advance_queued(&mut out, 0, 3);
        assert_eq!((front, out.len()), (3, 3));
        // Finish frame one, eat into frame two.
        let front = advance_queued(&mut out, front, 3);
        assert_eq!((front, out.len()), (2, 2));
        // Everything else.
        let front = advance_queued(&mut out, front, 6);
        assert_eq!((front, out.len()), (0, 0));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_reports_readable_socket() {
        use std::io::Write as _;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 7).unwrap();
        let mut events = Vec::new();
        // Nothing to read yet.
        ep.wait(Duration::from_millis(10), &mut events);
        assert!(events.is_empty(), "unexpected events: {events:?}");
        // One byte makes the socket readable with our token.
        client.write_all(&[1]).unwrap();
        client.flush().unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        while events.is_empty() && Instant::now() < deadline {
            ep.wait(Duration::from_millis(50), &mut events);
        }
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].0, 7);
        assert_ne!(events[0].1 & EPOLLIN, 0);
        ep.del(server.as_raw_fd()).unwrap();
    }
}
