//! The fabric server: one process, one [`Coordinator`], many TCP
//! clients, served by one of two data planes (`--data-plane`):
//!
//! * **threads** (the bit-exact reference): each accepted connection
//!   gets a read thread (decoding frames, submitting to the
//!   coordinator) and a write thread (serializing replies).
//! * **epoll**: a single readiness loop multiplexes every connection
//!   over nonblocking sockets (see [`super::reactor`]) — same frames,
//!   same FIFO reply order, same rejection semantics, no thread pair
//!   per connection.
//!
//! Replies are written strictly in request order per connection: the
//! writer blocks on (or, on the reactor, polls) each submit's
//! coordinator reply channel in FIFO order, which is safe because the
//! coordinator always resolves every request (a value or an explicit
//! error — never a dropped channel, see `coordinator::server`). That
//! FIFO also means a control request (metrics/health) sent on a busy
//! data connection queues behind the in-flight submits —
//! latency-sensitive probes belong on their own short-lived
//! connection, which is exactly what `fabric::router` does.
//!
//! Shutdown has two triggers: a remote [`Msg::Shutdown`] frame flips
//! the stop flag (acked first) so a `remus fabric-serve` process can be
//! stopped by its fleet parent, and a local [`FabricServer::shutdown`]
//! closes the listener and every connection, then drains the
//! coordinator.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{render_prometheus, Coordinator, CoordinatorConfig, RequestResult};
use crate::telemetry::{mint_boot_epoch, WalConfig, WalFlusher};

use super::auth::{client_split, server_split, FrameReader, FrameWriter, Psk};
use super::metrics_http::MetricsHttp;
use super::reactor::{self, DataPlane};
use super::wire::Msg;

/// How often a registered shard re-announces itself to the router
/// (`fabric-serve --register`). Registration is idempotent on the
/// router side (an unchanged name+endpoint is a silent refresh), and
/// the periodic re-announce is what lets a *restarted* router — which
/// comes up with an empty fleet — rediscover every shard within one
/// refresh period, each at its previously assigned ring slot.
pub const REG_REFRESH: Duration = Duration::from_millis(500);

/// How long the threads plane lets a reply write block before giving
/// up on the connection. Without a bound, a peer that stops draining
/// its socket wedges that connection's writer thread — and the handle
/// it pins — forever; with it, the writer errors out and shuts the
/// socket down so the reader unblocks too. (The epoll plane bounds the
/// same hazard in bytes instead: see
/// [`super::reactor::MAX_CONN_BACKLOG`].)
pub const DEFAULT_REPLY_WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Exponential accept-error backoff: start here, double up to the cap.
pub(crate) const ACCEPT_BACKOFF_START: Duration = Duration::from_millis(10);
pub(crate) const ACCEPT_BACKOFF_MAX: Duration = Duration::from_secs(1);

/// Observability options for a fabric server (§Observability, wire
/// v6): the durable flight recorder and the scrape endpoint. Both are
/// off by default; [`FabricServer::start_with_auth`] keeps its exact
/// pre-v6 behaviour apart from the (always minted) boot epoch.
pub struct ServeOptions {
    /// Fleet PSK (see [`FabricServer::start_with_auth`]).
    pub psk: Option<Psk>,
    /// `--journal-dir`: spill the reliability journal into a
    /// checksummed segment WAL under this directory (a fresh segment
    /// stamped with this boot's epoch; nothing is ever replayed).
    pub journal_dir: Option<PathBuf>,
    /// `--metrics-addr`: serve the Prometheus text exposition over
    /// plain HTTP at this address (see [`super::metrics_http`]).
    pub metrics_addr: Option<String>,
    /// WAL tuning (segment size, footprint bound, fsync policy).
    pub wal: WalConfig,
    /// `--data-plane`: the connection transport (§Scale). The default
    /// honours the `REMUS_DATA_PLANE` environment override so the
    /// integration suites can re-run unchanged under either plane.
    pub data_plane: DataPlane,
    /// Threads-plane reply write bound (see
    /// [`DEFAULT_REPLY_WRITE_TIMEOUT`]).
    pub reply_write_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            psk: None,
            journal_dir: None,
            metrics_addr: None,
            wal: WalConfig::default(),
            data_plane: DataPlane::from_env_or(DataPlane::Threads),
            reply_write_timeout: DEFAULT_REPLY_WRITE_TIMEOUT,
        }
    }
}

/// A reply the connection's writer (thread or reactor) must deliver,
/// in order.
pub(crate) enum Reply {
    /// A submitted request: block on the coordinator's reply channel.
    Pending(u64, Receiver<RequestResult>),
    /// An immediate control reply (metrics/health/ack).
    Now(Msg),
}

/// Outcome of dispatching one inbound message. Both data planes route
/// every message through [`dispatch_msg`], so the reactor answers
/// byte-identically to the threads reference.
pub(crate) enum Dispatch {
    /// Queue this reply behind everything already queued (FIFO).
    Reply(Reply),
    /// Queue the ack, then stop the whole server.
    Shutdown(Reply),
    /// Protocol violation: drop the connection.
    Violation,
}

/// Handle one inbound message against the coordinator — the single
/// dispatch path shared by `conn_loop` (threads) and the reactor.
pub(crate) fn dispatch_msg(
    msg: Msg,
    coord: &Coordinator,
    auth_rejects: &AtomicU64,
    boot_epoch: u64,
) -> Dispatch {
    match msg {
        Msg::Submit { id, kind, a, b, trace } => {
            // The trace id (wire v5, 0 = untraced) was minted by the
            // router; carrying it into the coordinator lets this shard
            // record the worker-side stage spans of the same
            // end-to-end timeline.
            let rx = coord.submit_traced(kind, a, b, trace);
            Dispatch::Reply(Reply::Pending(id, rx))
        }
        Msg::MetricsReq => {
            let mut m = coord.metrics();
            m.auth_rejects = auth_rejects.load(Ordering::SeqCst);
            Dispatch::Reply(Reply::Now(Msg::MetricsReply(m)))
        }
        Msg::HealthReq => {
            let m = coord.metrics();
            Dispatch::Reply(Reply::Now(Msg::HealthReply {
                serving: coord.is_serving(),
                workers: m.worker_health.len() as u32,
                routable: coord.healthy_workers() as u32,
                retired: m.retired_workers() as u32,
            }))
        }
        Msg::Ping { nonce } => {
            // Data-path heartbeat (wire v3): echo the nonce through the
            // ordinary FIFO reply stream. Behind a deep backlog the
            // pong queues after the pending results — which is fine,
            // because any frame the router reads (results included)
            // proves this connection is not half-open.
            Dispatch::Reply(Reply::Now(Msg::Pong { nonce }))
        }
        Msg::Events { since } => {
            // §Telemetry (wire v5): incremental journal pull. The reply
            // carries this shard's events at-or-past the caller's
            // cursor plus the next cursor value; the router merges
            // replies fleet-wide with per-shard cursors
            // (`Router::fleet_events`). The boot epoch (wire v6) lets
            // the router detect that this process restarted — sequence
            // numbers restarted at 0 — and reset its cursor instead of
            // stalling.
            let (events, latest) = coord.journal().since(since);
            Dispatch::Reply(Reply::Now(Msg::EventsReply { latest, events, boot_epoch }))
        }
        Msg::SpansReq => {
            // §Telemetry (wire v5): dump this shard's recorded stage
            // spans (empty unless `--trace-sample` is on).
            let spans = coord.tracer().spans();
            Dispatch::Reply(Reply::Now(Msg::SpansReply { spans }))
        }
        Msg::Shutdown => Dispatch::Shutdown(Reply::Now(Msg::ShutdownAck)),
        // Server-to-client messages (or registration traffic, which
        // belongs on the router's registration port) arriving at the
        // server: protocol violation, drop the connection.
        Msg::Result { .. }
        | Msg::MetricsReply(_)
        | Msg::HealthReply { .. }
        | Msg::ShutdownAck
        | Msg::Register { .. }
        | Msg::Welcome { .. }
        | Msg::Pong { .. }
        | Msg::EventsReply { .. }
        | Msg::SpansReply { .. } => Dispatch::Violation,
    }
}

/// Render a resolved coordinator reply as its wire message.
pub(crate) fn result_msg(id: u64, r: RequestResult) -> Msg {
    Msg::Result {
        id,
        value: r.value,
        latency_us: r.latency.as_micros() as u64,
        error: r.error,
    }
}

/// Defensive reply for a dropped coordinator channel. The coordinator
/// guarantees a reply, so this should never fire — but if it ever
/// does, the client sees an explicit error, not a hung request.
pub(crate) fn dropped_result_msg(id: u64) -> Msg {
    Msg::Result {
        id,
        value: 0,
        latency_us: 0,
        error: Some("coordinator dropped the reply channel".to_string()),
    }
}

/// Classify an `accept` error: transient kinds — aborted/reset
/// connections racing the accept, signal interruptions, fd exhaustion
/// (ENFILE/EMFILE, which recovers when connections close) — deserve a
/// bounded-backoff retry. Anything else is a dead listener.
pub(crate) fn transient_accept_error(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::Interrupted
    ) || matches!(e.raw_os_error(), Some(23) | Some(24)) // ENFILE / EMFILE
}

/// One fabric endpoint fronting an in-process [`Coordinator`].
pub struct FabricServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    /// Stream clones kept so a local shutdown can unblock the per-
    /// connection read loops (blocking reads, no timeouts). Keyed by
    /// connection id; each connection removes itself on exit, so
    /// short-lived control connections (metrics/health probes) don't
    /// leak fds over a long-running server's lifetime.
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Background registration client (`register_with`), joined at
    /// shutdown (it exits on success or when the stop flag flips).
    reg_handle: Mutex<Option<JoinHandle<()>>>,
    coord: Arc<Coordinator>,
    /// Fleet PSK (`--psk-file`). `Some` makes every connection — data
    /// and registration — handshake and seal; `None` keeps the
    /// plaintext v3 behaviour for mixed-version transitions.
    psk: Arc<Option<Psk>>,
    /// Peers this server rejected: failed handshakes, plaintext clients
    /// on a sealed port, tampered frames. Stamped onto metrics replies.
    auth_rejects: Arc<AtomicU64>,
    /// This boot's random non-zero epoch (wire v6), stamped into every
    /// `EventsReply` so the router can tell a restart from a quiet
    /// shard, and onto any WAL segments this process writes.
    boot_epoch: u64,
    /// Background journal→WAL flusher (`--journal-dir`), stopped with
    /// a final drain at shutdown.
    wal: Option<WalFlusher>,
    /// The `/metrics` scrape endpoint (`--metrics-addr`).
    metrics_http: Option<MetricsHttp>,
}

impl FabricServer {
    /// Bind `addr` (use port 0 for an ephemeral loopback port) and
    /// start serving a freshly started coordinator, plaintext.
    pub fn start(addr: &str, cfg: CoordinatorConfig) -> Result<Self> {
        Self::start_with_auth(addr, cfg, None)
    }

    /// [`FabricServer::start`] with an optional fleet PSK: when `Some`,
    /// every accepted connection must complete the PSK handshake before
    /// a single frame reaches the coordinator, and all traffic is
    /// sealed (see [`crate::fabric::auth`]).
    pub fn start_with_auth(addr: &str, cfg: CoordinatorConfig, psk: Option<Psk>) -> Result<Self> {
        Self::start_with_options(addr, cfg, ServeOptions { psk, ..ServeOptions::default() })
    }

    /// The full constructor: PSK plus the flight-recorder options. A
    /// boot epoch is always minted (epoch-aware `EventsReply` costs 8
    /// bytes per pull); the WAL flusher and the `/metrics` endpoint
    /// spawn only when their options are set.
    pub fn start_with_options(
        addr: &str,
        cfg: CoordinatorConfig,
        opts: ServeOptions,
    ) -> Result<Self> {
        let coord = Arc::new(Coordinator::start(cfg)?);
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding fabric server to {addr}"))?;
        let addr = listener.local_addr()?;
        // Non-blocking accept so the loop can observe the stop flag.
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let psk = Arc::new(opts.psk);
        let auth_rejects = Arc::new(AtomicU64::new(0));
        let boot_epoch = mint_boot_epoch();
        let wal = match &opts.journal_dir {
            Some(dir) => Some(
                WalFlusher::spawn(Arc::clone(coord.journal()), dir, boot_epoch, opts.wal)
                    .with_context(|| format!("opening journal WAL in {}", dir.display()))?,
            ),
            None => None,
        };
        let metrics_http = match &opts.metrics_addr {
            Some(maddr) => {
                let coord = coord.clone();
                let auth_rejects = auth_rejects.clone();
                Some(MetricsHttp::serve(maddr, move || {
                    let mut m = coord.metrics();
                    m.auth_rejects = auth_rejects.load(Ordering::SeqCst);
                    render_prometheus(&m, boot_epoch)
                })?)
            }
            None => None,
        };
        let mut data_plane = opts.data_plane;
        if data_plane == DataPlane::Epoll && !reactor::supported() {
            eprintln!(
                "fabric server: --data-plane epoll is not supported on this platform, \
                 falling back to threads"
            );
            data_plane = DataPlane::Threads;
        }
        let accept_handle = {
            let coord = coord.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            let conn_handles = conn_handles.clone();
            let psk = psk.clone();
            let auth_rejects = auth_rejects.clone();
            let reply_write_timeout = opts.reply_write_timeout;
            match data_plane {
                DataPlane::Threads => std::thread::spawn(move || {
                    accept_loop(
                        listener,
                        coord,
                        stop,
                        conns,
                        conn_handles,
                        psk,
                        auth_rejects,
                        boot_epoch,
                        reply_write_timeout,
                    )
                }),
                DataPlane::Epoll => std::thread::spawn(move || {
                    reactor::serve_reactor(
                        listener,
                        coord,
                        stop,
                        conns,
                        conn_handles,
                        psk,
                        auth_rejects,
                        boot_epoch,
                    )
                }),
            }
        };
        Ok(Self {
            addr,
            stop,
            accept_handle: Some(accept_handle),
            conns,
            conn_handles,
            reg_handle: Mutex::new(None),
            coord,
            psk,
            auth_rejects,
            boot_epoch,
            wal,
            metrics_http,
        })
    }

    /// This boot's random non-zero epoch (wire v6).
    pub fn boot_epoch(&self) -> u64 {
        self.boot_epoch
    }

    /// The `/metrics` endpoint address, when one was configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_http.as_ref().map(|m| m.local_addr())
    }

    /// Announce this shard to a router's registration endpoint
    /// (`fabric-serve --register`): a background loop that retries
    /// until the router answers with a `Welcome`, then keeps
    /// re-announcing every [`REG_REFRESH`] until this server stops —
    /// registration commonly precedes router startup in a real
    /// deployment, so an unreachable router is not an error, and the
    /// refresh loop is what survives a *router* restart: a fresh router
    /// has an empty fleet until the next refresh lands. The shard
    /// remembers the slot index each `Welcome` assigned and sends it
    /// back as `prev`, so a restarted router reconstructs every shard
    /// at its old index and the rebuilt ring is bit-identical. `name`
    /// is the shard's stable identity (re-registering under the same
    /// name after a shard restart reclaims the same ring slot); `spare`
    /// joins the router's hot-spare pool instead of the active ring.
    pub fn register_with(&self, router_reg: &str, name: &str, spare: bool) {
        let stop = self.stop.clone();
        let (name, addr) = (name.to_string(), self.addr.to_string());
        let router_reg = router_reg.to_string();
        let psk = self.psk.clone();
        let handle = std::thread::spawn(move || {
            let mut assigned: Option<u32> = None;
            while !stop.load(Ordering::SeqCst) {
                let msg = Msg::Register {
                    name: name.clone(),
                    addr: addr.clone(),
                    spare,
                    prev: assigned,
                };
                match register_once(&router_reg, &msg, (*psk).as_ref()) {
                    Ok((shard, active)) => {
                        // Log first contact and slot moves, not the
                        // twice-a-second refresh chatter.
                        if assigned != Some(shard) {
                            eprintln!(
                                "fabric server: registered with {router_reg} as shard {shard} \
                                 ({})",
                                if active { "active" } else { "spare" }
                            );
                            assigned = Some(shard);
                        }
                        sleep_unless_stopped(&stop, REG_REFRESH);
                    }
                    Err(_) => sleep_unless_stopped(&stop, Duration::from_millis(200)),
                }
            }
        });
        *self.reg_handle.lock().unwrap() = Some(handle);
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a remote `Shutdown` frame (or a local stop) landed.
    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Block until a remote `Shutdown` frame stops this server (the
    /// `remus fabric-serve` foreground loop).
    pub fn wait(&self) {
        while !self.is_stopped() {
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Stop accepting, close every connection, join the threads, and
    /// drain the coordinator.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.reg_handle.lock().unwrap().take() {
            let _ = h.join();
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // Unblock the connection read loops.
        for (_, conn) in self.conns.lock().unwrap().drain() {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        let handles: Vec<_> = self.conn_handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        // All connection threads are joined, so this is the last Arc.
        if let Ok(coord) = Arc::try_unwrap(self.coord) {
            coord.shutdown();
        }
        // Stop the flusher *after* the coordinator drained, so any
        // final reliability events make it into the WAL; its stop path
        // performs one last journal drain.
        if let Some(wal) = self.wal.take() {
            wal.stop();
        }
        if let Some(m) = self.metrics_http.take() {
            m.shutdown();
        }
    }
}

/// Sleep in short slices so the registration loop notices a shutdown
/// within tens of milliseconds instead of a full refresh period.
pub(crate) fn sleep_unless_stopped(stop: &AtomicBool, total: Duration) {
    let deadline = std::time::Instant::now() + total;
    while !stop.load(Ordering::SeqCst) {
        let left = deadline.saturating_duration_since(std::time::Instant::now());
        if left.is_zero() {
            return;
        }
        std::thread::sleep(left.min(Duration::from_millis(20)));
    }
}

/// One registration attempt: connect to the router's registration
/// port, handshake when a PSK is configured, send the `Register`,
/// await the `Welcome`.
fn register_once(router_reg: &str, msg: &Msg, psk: Option<&Psk>) -> Result<(u32, bool)> {
    let stream = super::router::control_connect(router_reg)?;
    let (mut reader, mut writer) =
        client_split(stream, psk, Some(super::router::CONTROL_TIMEOUT))?;
    writer.send(msg)?;
    match reader.recv()? {
        Some(Msg::Welcome { shard, active }) => Ok((shard, active)),
        other => anyhow::bail!("unexpected reply to Register: {other:?}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    psk: Arc<Option<Psk>>,
    auth_rejects: Arc<AtomicU64>,
    boot_epoch: u64,
    reply_write_timeout: Duration,
) {
    let mut next_conn_id = 0u64;
    let mut backoff = ACCEPT_BACKOFF_START;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                backoff = ACCEPT_BACKOFF_START;
                let _ = stream.set_nodelay(true);
                // The accepted socket is non-blocking (inherited on some
                // platforms): force blocking semantics for the framed
                // read/write loops.
                let _ = stream.set_nonblocking(false);
                let conn_id = next_conn_id;
                next_conn_id += 1;
                if let Ok(clone) = stream.try_clone() {
                    conns.lock().unwrap().insert(conn_id, clone);
                }
                let coord = coord.clone();
                let stop = stop.clone();
                let conns = conns.clone();
                let psk = psk.clone();
                let auth_rejects = auth_rejects.clone();
                // The handshake runs inside the connection thread, never
                // here: a hostile peer that stalls its handshake (or
                // trickles bytes) costs one bounded thread, not the
                // accept loop.
                let handle = std::thread::spawn(move || {
                    match server_split(stream, (*psk).as_ref(), None) {
                        Ok((reader, writer)) => conn_loop(
                            reader,
                            writer,
                            coord,
                            stop,
                            &auth_rejects,
                            boot_epoch,
                            reply_write_timeout,
                        ),
                        Err(e) => {
                            auth_rejects.fetch_add(1, Ordering::SeqCst);
                            eprintln!("fabric server: rejected peer: {e:#}");
                        }
                    }
                    conns.lock().unwrap().remove(&conn_id);
                });
                // Reap finished connection threads so a long-running
                // server doesn't accumulate a handle per short-lived
                // control connection.
                let mut handles = conn_handles.lock().unwrap();
                handles.retain(|h| !h.is_finished());
                handles.push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) if transient_accept_error(&e) => {
                // One aborted connection (or a signal, or a transient
                // fd-exhaustion spike) must not kill the listener — that
                // would turn a blip into a permanently dead shard. Back
                // off and keep accepting.
                eprintln!(
                    "fabric server: transient accept error (retrying in {backoff:?}): {e}"
                );
                sleep_unless_stopped(&stop, backoff);
                backoff = (backoff * 2).min(ACCEPT_BACKOFF_MAX);
            }
            Err(e) => {
                // A persistent accept failure makes this endpoint
                // unreachable — including for remote Shutdown frames —
                // so flip the stop flag too: better a clean `wait()`
                // return than a zombie shard.
                eprintln!("fabric server: FATAL: accept failed, stopping listener: {e}");
                stop.store(true, Ordering::SeqCst);
                break;
            }
        }
    }
}

fn conn_loop(
    mut reader: FrameReader,
    writer: FrameWriter,
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    auth_rejects: &AtomicU64,
    boot_epoch: u64,
    reply_write_timeout: Duration,
) {
    // The handshake (when one ran) left a short write timeout on the
    // socket. The data path gets a *bounded* one: a peer that stops
    // draining its replies must error the writer out (which shuts the
    // socket down and unblocks this reader), not wedge the connection
    // pair forever.
    let _ = writer.stream().set_write_timeout(Some(reply_write_timeout));
    let sealed = reader.is_sealed();
    let (reply_tx, reply_rx) = channel::<Reply>();
    let writer = std::thread::spawn(move || writer_loop(writer, reply_rx));
    loop {
        let msg = match reader.recv() {
            Ok(Some(m)) => m,
            // Clean close or local shutdown: this connection is done.
            Ok(None) => break,
            Err(_) => {
                // A malformed frame drops the connection, never the
                // process; on a sealed connection it is a tampered or
                // replayed frame and counts as an auth reject.
                if sealed {
                    auth_rejects.fetch_add(1, Ordering::SeqCst);
                }
                break;
            }
        };
        match dispatch_msg(msg, &coord, auth_rejects, boot_epoch) {
            Dispatch::Reply(reply) => {
                if reply_tx.send(reply).is_err() {
                    break;
                }
            }
            Dispatch::Shutdown(ack) => {
                let _ = reply_tx.send(ack);
                stop.store(true, Ordering::SeqCst);
                break;
            }
            Dispatch::Violation => break,
        }
    }
    // Closing the reply channel lets the writer drain the pending
    // results (every coordinator request resolves) and exit.
    drop(reply_tx);
    let _ = writer.join();
}

fn writer_loop(mut writer: FrameWriter, reply_rx: Receiver<Reply>) {
    while let Ok(reply) = reply_rx.recv() {
        let msg = match reply {
            Reply::Now(m) => m,
            Reply::Pending(id, result_rx) => match result_rx.recv() {
                Ok(r) => result_msg(id, r),
                // Defensive: the coordinator guarantees a reply; if the
                // channel ever drops, surface it as an explicit error.
                Err(_) => dropped_result_msg(id),
            },
        };
        if writer.send(&msg).is_err() {
            // Peer gone, or not draining within the bounded write
            // timeout. Shut the socket down so the read loop unblocks
            // too (its reads have no timeout) — otherwise a wedged
            // writer would still pin the connection pair.
            let _ = writer.stream().shutdown(std::net::Shutdown::Both);
            break;
        }
    }
}
