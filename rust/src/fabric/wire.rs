//! The fabric wire protocol: length-prefixed, versioned binary frames
//! over a byte stream (std `TcpStream` only — serde/tokio are not in
//! the offline vendor set, so every message hand-rolls `to_bytes` /
//! `from_bytes`).
//!
//! Frame layout:
//!
//! ```text
//! [len: u32 LE] [version: u8] [type: u8] [body ...]
//! ```
//!
//! `len` counts everything after the prefix (version + type + body).
//! All multi-byte integers are little-endian. Decoding is strict and
//! panic-free: unknown versions or types, truncated bodies, trailing
//! bytes and implausible lengths are all `Err` — a malformed peer can
//! kill its connection, never the process
//! (`rust/tests/prop_fabric_wire.rs`).

use std::io::{Read, Write};

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::{KindStats, MetricsSnapshot, WorkerHealth};
use crate::mmpu::functions::KIND_FAMILIES;
use crate::mmpu::FunctionKind;
use crate::telemetry::{Event, EventKind, Stage, TraceSpan};

/// Newest protocol version this peer speaks. v2 added shard
/// registration (`Register`/`Welcome`) and the fleet-membership
/// counters (`shards_total`/`shards_down`) trailing the metrics
/// snapshot body. v3 added the data-path heartbeat (`Ping`/`Pong`), the
/// optional previous-slot index trailing `Register` (so a fleet
/// re-registering with a restarted router reclaims its exact ring
/// indices), and the heartbeat counters trailing the snapshot body.
/// v4 added the authentication-reject counter (`auth_rejects`) trailing
/// the snapshot body; sealed transport (see [`crate::fabric::auth`])
/// wraps these same frames and is negotiated per connection, not per
/// version byte. v5 added telemetry (see [`crate::telemetry`]): an
/// optional trace id trailing `Submit` (only present — and only
/// v5-stamped — when nonzero), the observability counters trailing the
/// snapshot body (`uptime_ns`, latency overflow/exact max, per-kind
/// counters), and the control-plane `Events`/`EventsReply` +
/// `SpansReq`/`SpansReply` messages. v6 added the durable flight
/// recorder's epoch awareness (see [`crate::telemetry::wal`]): an
/// optional `boot_epoch` trailing `EventsReply` (only present — and
/// only v6-stamped — when nonzero), letting the router detect that a
/// shard restarted and its journal sequence numbers started over.
/// v7 added the §Perf list-scheduling packing counters (`plan_ops`,
/// `plan_bundles`) trailing the snapshot body.
/// Each frame is stamped with the *lowest* version that can represent
/// its message ([`Msg::min_version`]), so older peers keep
/// understanding the unchanged message layouts.
pub const WIRE_VERSION: u8 = 7;

/// Oldest version this decoder still accepts. v1/v2 frames decode
/// compatibly (the snapshot's missing membership/heartbeat counters
/// default to zero, a v2 `Register` carries no previous-slot index);
/// newer-version-only message types inside an older frame are
/// rejected, and anything outside `MIN_WIRE_VERSION..=WIRE_VERSION` is
/// an error — never a panic, never a misparse.
pub const MIN_WIRE_VERSION: u8 = 1;

/// Sanity bound on a frame body: protects against garbage length
/// prefixes allocating gigabytes (16 MiB is orders of magnitude above
/// any real fabric message).
pub const MAX_FRAME: usize = 1 << 24;

/// Bytes of length prefix ahead of every frame body (`u32` LE). The
/// blocking readers consume it with a fixed-size `read_exact`; the
/// epoll data plane's incremental decoder
/// ([`crate::fabric::auth::FrameDecoder`]) buffers until at least this
/// many bytes have arrived before it can even learn the body length.
pub const FRAME_HEADER_LEN: usize = 4;

/// One fabric message. Submits carry a client-chosen `id` echoed by the
/// matching `Result`, so responses can be delivered out of order and
/// retried requests re-keyed across shards.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Client -> server: execute `kind(a, b)`. `trace` (wire v5) is the
    /// request's fleet-wide trace id — 0 means untraced, and an
    /// untraced submit keeps the exact v1 layout so old shards
    /// interoperate (see [`crate::telemetry`]).
    Submit { id: u64, kind: FunctionKind, a: u64, b: u64, trace: u64 },
    /// Server -> client: outcome of the `Submit` with the same `id`.
    /// `error` mirrors [`crate::coordinator::RequestResult::error`].
    Result { id: u64, value: u64, latency_us: u64, error: Option<String> },
    /// Client -> server: request a metrics snapshot.
    MetricsReq,
    MetricsReply(MetricsSnapshot),
    /// Client -> server: non-blocking capacity probe.
    HealthReq,
    HealthReply { serving: bool, workers: u32, routable: u32, retired: u32 },
    /// Client -> server: stop serving (acked, then the server exits its
    /// accept loop; in-flight work still drains).
    Shutdown,
    ShutdownAck,
    /// Shard -> router (registration port, wire v2): announce a serving
    /// shard. `name` is the shard's stable identity — a restarted
    /// process re-registering under the same name reclaims its ring
    /// slot (possibly at a new `addr`), keeping kind->shard placement
    /// bit-identical across the restart. `spare` asks to join the
    /// hot-spare pool instead of the active ring. `prev` (wire v3) is
    /// the slot index a previous `Welcome` assigned, remembered by the
    /// shard across *router* restarts: a fresh router reconstructs each
    /// registrant at its old index regardless of re-registration order,
    /// so the rebuilt ring is bit-identical to the crashed router's.
    Register { name: String, addr: String, spare: bool, prev: Option<u32> },
    /// Router -> shard (wire v2): registration ack with the assigned
    /// stable shard index and whether the shard is immediately part of
    /// the routing ring (spares start idle).
    Welcome { shard: u32, active: bool },
    /// Router -> shard (data connection, wire v3): data-path liveness
    /// probe. Control-plane health probes cannot catch a peer whose TCP
    /// connection is half-open (accepts writes, never replies); an
    /// unanswered `Ping` on the *data* path does.
    Ping { nonce: u64 },
    /// Shard -> router (wire v3): echo of a `Ping`'s nonce. Rides the
    /// connection's ordinary FIFO reply stream, so any inbound frame —
    /// a `Result` ahead of the pong included — proves liveness.
    Pong { nonce: u64 },
    /// Client/router -> shard (wire v5): pull the shard's reliability
    /// event journal from sequence number `since` on (a resumable
    /// cursor — see `telemetry::EventJournal::since`).
    Events { since: u64 },
    /// Shard -> client (wire v5): journal slice plus the cursor to
    /// resume from (`latest` always advances, even past entries the
    /// bounded journal already overwrote). `boot_epoch` (wire v6) is
    /// the replying process's random per-boot identity — a change on
    /// the same slot means the process restarted and its journal
    /// restarted at seq 0, so the puller must reset its cursor. 0
    /// means "not epoch-aware", and an epoch-less reply keeps the
    /// exact v5 layout so old pullers interoperate.
    EventsReply { latest: u64, events: Vec<Event>, boot_epoch: u64 },
    /// Client/router -> shard (wire v5): pull the shard's retained
    /// sampled trace spans.
    SpansReq,
    SpansReply { spans: Vec<TraceSpan> },
}

impl Msg {
    fn type_id(&self) -> u8 {
        match self {
            Msg::Submit { .. } => 1,
            Msg::Result { .. } => 2,
            Msg::MetricsReq => 3,
            Msg::MetricsReply(_) => 4,
            Msg::HealthReq => 5,
            Msg::HealthReply { .. } => 6,
            Msg::Shutdown => 7,
            Msg::ShutdownAck => 8,
            Msg::Register { .. } => 9,
            Msg::Welcome { .. } => 10,
            Msg::Ping { .. } => 11,
            Msg::Pong { .. } => 12,
            Msg::Events { .. } => 13,
            Msg::EventsReply { .. } => 14,
            Msg::SpansReq => 15,
            Msg::SpansReply { .. } => 16,
        }
    }

    /// Lowest protocol version that can represent this message. Frames
    /// are stamped with this (not blindly with [`WIRE_VERSION`]) so a
    /// mixed-version fleet interoperates on the data path: a v1 peer
    /// accepts every message whose layout predates v2, and only the
    /// genuinely newer messages (registration; heartbeats; metrics
    /// snapshots, whose body grew the membership then the heartbeat
    /// counters; a `Register` carrying a previous-slot index) are
    /// labeled with the version that introduced them.
    fn min_version(&self) -> u8 {
        match self {
            // An epoch-stamped journal reply carries the trailing
            // boot epoch; an epoch-less one keeps the exact v5 layout
            // for old pullers.
            Msg::EventsReply { boot_epoch, .. } if *boot_epoch != 0 => 6,
            // The snapshot body always carries the trailing packing
            // counters now, so a metrics reply is a v7 message.
            Msg::MetricsReply(_) => 7,
            Msg::Events { .. }
            | Msg::EventsReply { .. }
            | Msg::SpansReq
            | Msg::SpansReply { .. } => 5,
            // A traced submit carries the trailing trace id; an
            // untraced one keeps the exact v1 layout for old shards.
            Msg::Submit { trace, .. } if *trace != 0 => 5,
            Msg::Ping { .. } | Msg::Pong { .. } => 3,
            Msg::Register { prev: Some(_), .. } => 3,
            Msg::Register { prev: None, .. } | Msg::Welcome { .. } => 2,
            _ => 1,
        }
    }

    /// Encode as a frame payload (version + type + body, no length
    /// prefix — [`write_msg`] adds that).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.push(self.min_version());
        out.push(self.type_id());
        match self {
            Msg::Submit { id, kind, a, b, trace } => {
                put_u64(&mut out, *id);
                put_kind(&mut out, *kind);
                put_u64(&mut out, *a);
                put_u64(&mut out, *b);
                // The trace id trails the v1 body, and only in
                // v5-stamped frames (untraced submits keep the exact
                // v1 layout for old shards).
                if *trace != 0 {
                    put_u64(&mut out, *trace);
                }
            }
            Msg::Result { id, value, latency_us, error } => {
                put_u64(&mut out, *id);
                put_u64(&mut out, *value);
                put_u64(&mut out, *latency_us);
                match error {
                    None => out.push(0),
                    Some(e) => {
                        out.push(1);
                        put_string(&mut out, e);
                    }
                }
            }
            Msg::MetricsReq | Msg::HealthReq | Msg::Shutdown | Msg::ShutdownAck => {}
            Msg::MetricsReply(s) => put_snapshot(&mut out, s),
            Msg::HealthReply { serving, workers, routable, retired } => {
                out.push(*serving as u8);
                put_u32(&mut out, *workers);
                put_u32(&mut out, *routable);
                put_u32(&mut out, *retired);
            }
            Msg::Register { name, addr, spare, prev } => {
                put_string(&mut out, name);
                put_string(&mut out, addr);
                out.push(*spare as u8);
                // The previous-slot index trails the v2 body, and only
                // in v3-stamped frames (prev-less registrations keep the
                // exact v2 layout for old routers).
                if let Some(p) = prev {
                    out.push(1);
                    put_u32(&mut out, *p);
                }
            }
            Msg::Welcome { shard, active } => {
                put_u32(&mut out, *shard);
                out.push(*active as u8);
            }
            Msg::Ping { nonce } | Msg::Pong { nonce } => put_u64(&mut out, *nonce),
            Msg::Events { since } => put_u64(&mut out, *since),
            Msg::EventsReply { latest, events, boot_epoch } => {
                put_u64(&mut out, *latest);
                put_u32(&mut out, events.len() as u32);
                for e in events {
                    put_event(&mut out, e);
                }
                // The boot epoch trails the v5 body, and only in
                // v6-stamped frames (epoch-less replies keep the
                // exact v5 layout for old pullers).
                if *boot_epoch != 0 {
                    put_u64(&mut out, *boot_epoch);
                }
            }
            Msg::SpansReq => {}
            Msg::SpansReply { spans } => {
                put_u32(&mut out, spans.len() as u32);
                for s in spans {
                    put_span(&mut out, s);
                }
            }
        }
        out
    }

    /// Decode a frame payload. Strict: every byte must be consumed.
    /// Accepts `MIN_WIRE_VERSION..=WIRE_VERSION`; older peers' frames
    /// decode with version-appropriate layouts, newer (or garbage)
    /// versions are rejected outright.
    pub fn from_bytes(bytes: &[u8]) -> Result<Msg> {
        let mut c = Cursor { buf: bytes, pos: 0 };
        let version = c.u8()?;
        ensure!(
            (MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version),
            "unsupported wire version {version} (this peer speaks {MIN_WIRE_VERSION}..={WIRE_VERSION})"
        );
        let type_id = c.u8()?;
        let msg = match type_id {
            1 => {
                let id = c.u64()?;
                let kind = c.kind()?;
                let a = c.u64()?;
                let b = c.u64()?;
                // v5 appended the trace id; only traced submits are
                // v5-stamped, so the field is present iff version >= 5.
                let trace = if version >= 5 { c.u64()? } else { 0 };
                Msg::Submit { id, kind, a, b, trace }
            }
            2 => {
                let id = c.u64()?;
                let value = c.u64()?;
                let latency_us = c.u64()?;
                let error = match c.u8()? {
                    0 => None,
                    1 => Some(c.string()?),
                    f => bail!("invalid option flag {f}"),
                };
                Msg::Result { id, value, latency_us, error }
            }
            3 => Msg::MetricsReq,
            4 => Msg::MetricsReply(c.snapshot(version)?),
            5 => Msg::HealthReq,
            6 => {
                let serving = c.bool()?;
                let workers = c.u32()?;
                let routable = c.u32()?;
                let retired = c.u32()?;
                Msg::HealthReply { serving, workers, routable, retired }
            }
            7 => Msg::Shutdown,
            8 => Msg::ShutdownAck,
            9 | 10 if version < 2 => {
                bail!("message type {} requires wire version >= 2 (frame is v{version})", type_id)
            }
            11 | 12 if version < 3 => {
                bail!("message type {} requires wire version >= 3 (frame is v{version})", type_id)
            }
            13..=16 if version < 5 => {
                bail!("message type {} requires wire version >= 5 (frame is v{version})", type_id)
            }
            9 => {
                let name = c.string()?;
                let addr = c.string()?;
                let spare = c.bool()?;
                // v3 appended the optional previous-slot index; a v2
                // frame's body ends at the spare flag.
                let prev = if version >= 3 {
                    match c.u8()? {
                        0 => None,
                        1 => Some(c.u32()?),
                        f => bail!("invalid option flag {f}"),
                    }
                } else {
                    None
                };
                Msg::Register { name, addr, spare, prev }
            }
            10 => {
                let shard = c.u32()?;
                let active = c.bool()?;
                Msg::Welcome { shard, active }
            }
            11 => Msg::Ping { nonce: c.u64()? },
            12 => Msg::Pong { nonce: c.u64()? },
            13 => Msg::Events { since: c.u64()? },
            14 => {
                let latest = c.u64()?;
                let n = c.u32()? as usize;
                ensure!(n <= 1 << 16, "implausible event count {n}");
                let mut events = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    events.push(c.event()?);
                }
                // v6 appended the boot epoch; only epoch-stamped
                // replies are v6-stamped, so the field is present iff
                // version >= 6.
                let boot_epoch = if version >= 6 { c.u64()? } else { 0 };
                Msg::EventsReply { latest, events, boot_epoch }
            }
            15 => Msg::SpansReq,
            16 => {
                let n = c.u32()? as usize;
                ensure!(n <= 1 << 20, "implausible span count {n}");
                let mut spans = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    spans.push(c.span()?);
                }
                Msg::SpansReply { spans }
            }
            t => bail!("unknown message type {t}"),
        };
        ensure!(c.pos == bytes.len(), "trailing bytes after {} message", type_name(type_id));
        Ok(msg)
    }
}

fn type_name(t: u8) -> &'static str {
    match t {
        1 => "Submit",
        2 => "Result",
        3 => "MetricsReq",
        4 => "MetricsReply",
        5 => "HealthReq",
        6 => "HealthReply",
        7 => "Shutdown",
        8 => "ShutdownAck",
        9 => "Register",
        10 => "Welcome",
        11 => "Ping",
        12 => "Pong",
        13 => "Events",
        14 => "EventsReply",
        15 => "SpansReq",
        16 => "SpansReply",
        _ => "unknown",
    }
}

/// Write one frame: length prefix + payload.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let payload = msg.to_bytes();
    ensure!(payload.len() <= MAX_FRAME, "frame too large: {} bytes", payload.len());
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. `Ok(None)` on a clean EOF at a frame boundary;
/// EOF mid-frame, an implausible length prefix, or a malformed payload
/// are errors.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<Msg>> {
    let mut len_buf = [0u8; 4];
    if !read_exact_or_eof(r, &mut len_buf)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!((2..=MAX_FRAME).contains(&len), "implausible frame length {len}");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(Msg::from_bytes(&payload)?))
}

/// Fill `buf` completely; `Ok(false)` when EOF arrives before the first
/// byte (a peer closing between frames), `Err` when it arrives mid-way.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                ensure!(got == 0, "eof mid-frame ({got} of {} header bytes)", buf.len());
                return Ok(false);
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_string(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_kind(out: &mut Vec<u8>, kind: FunctionKind) {
    let (tag, bits) = match kind {
        FunctionKind::Add(n) => (0u8, n),
        FunctionKind::Mul(n) => (1, n),
        FunctionKind::MulNaive(n) => (2, n),
        FunctionKind::Xor(n) => (3, n),
    };
    out.push(tag);
    put_u32(out, bits);
}

fn put_snapshot(out: &mut Vec<u8>, s: &MetricsSnapshot) {
    for v in [s.submitted, s.completed, s.failed, s.batches, s.batched_items, s.busy_ns,
        s.queue_depth]
    {
        put_u64(out, v);
    }
    put_u32(out, s.lat_bins.len() as u32);
    for &b in &s.lat_bins {
        put_u64(out, b);
    }
    put_u32(out, s.worker_health.len() as u32);
    for w in &s.worker_health {
        for v in [w.batches, w.scrubs, w.corrected, w.uncorrectable, w.stuck_detected,
            w.remapped_rows, w.spares_left]
        {
            put_u64(out, v);
        }
        out.push(w.policy_level);
        out.push(w.retired as u8);
    }
    // Fleet membership counters trail the v1 body so v1 frames decode
    // compatibly (they simply stop here and the counters default to 0).
    put_u64(out, s.shards_total);
    put_u64(out, s.shards_down);
    // Heartbeat counters trail the v2 body likewise (v3).
    put_u64(out, s.hb_pings);
    put_u64(out, s.hb_pongs);
    put_u64(out, s.hb_timeouts);
    // The authentication-reject counter trails the v3 body (v4).
    put_u64(out, s.auth_rejects);
    // The observability counters trail the v4 body (v5): uptime,
    // latency-histogram honesty (overflow count + exact max), and the
    // fixed-width per-kind-family attribution counters.
    put_u64(out, s.uptime_ns);
    put_u64(out, s.lat_overflow);
    put_u64(out, s.lat_max_us);
    for ks in &s.kind_stats {
        put_u64(out, ks.submitted);
        put_u64(out, ks.completed);
        put_u64(out, ks.failed);
    }
    // The list-scheduling packing counters trail the v5 body (v7).
    put_u64(out, s.plan_ops);
    put_u64(out, s.plan_bundles);
}

fn put_event(out: &mut Vec<u8>, e: &Event) {
    put_u64(out, e.seq);
    put_u32(out, e.shard);
    put_u64(out, e.at_ns);
    let (tag, a, b, c) = e.kind.to_words();
    out.push(tag);
    put_u64(out, a);
    put_u64(out, b);
    put_u64(out, c);
}

fn put_span(out: &mut Vec<u8>, s: &TraceSpan) {
    put_u64(out, s.trace);
    out.push(s.stage as u8);
    put_u64(out, s.start_ns);
    put_u64(out, s.dur_ns);
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| anyhow!("length overflow"))?;
        ensure!(end <= self.buf.len(), "truncated frame: need {n} bytes at offset {}", self.pos);
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b}"),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n <= MAX_FRAME, "implausible string length {n}");
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| anyhow!("invalid utf-8 in string"))
    }

    fn kind(&mut self) -> Result<FunctionKind> {
        let tag = self.u8()?;
        let bits = self.u32()?;
        ensure!((1..=64).contains(&bits), "operand bits {bits} out of range");
        Ok(match tag {
            0 => FunctionKind::Add(bits),
            1 => FunctionKind::Mul(bits),
            2 => FunctionKind::MulNaive(bits),
            3 => FunctionKind::Xor(bits),
            t => bail!("unknown function kind tag {t}"),
        })
    }

    fn snapshot(&mut self, version: u8) -> Result<MetricsSnapshot> {
        let submitted = self.u64()?;
        let completed = self.u64()?;
        let failed = self.u64()?;
        let batches = self.u64()?;
        let batched_items = self.u64()?;
        let busy_ns = self.u64()?;
        let queue_depth = self.u64()?;
        let nbins = self.u32()? as usize;
        ensure!(nbins <= 256, "implausible latency bin count {nbins}");
        let mut lat_bins = Vec::with_capacity(nbins);
        for _ in 0..nbins {
            lat_bins.push(self.u64()?);
        }
        let nworkers = self.u32()? as usize;
        ensure!(nworkers <= 1 << 20, "implausible worker count {nworkers}");
        let mut worker_health = Vec::with_capacity(nworkers.min(4096));
        for _ in 0..nworkers {
            let batches = self.u64()?;
            let scrubs = self.u64()?;
            let corrected = self.u64()?;
            let uncorrectable = self.u64()?;
            let stuck_detected = self.u64()?;
            let remapped_rows = self.u64()?;
            let spares_left = self.u64()?;
            let policy_level = self.u8()?;
            let retired = self.bool()?;
            worker_health.push(WorkerHealth {
                batches,
                scrubs,
                corrected,
                uncorrectable,
                stuck_detected,
                remapped_rows,
                spares_left,
                policy_level,
                retired,
            });
        }
        // v2 appended the fleet membership counters, v3 the heartbeat
        // counters; an older peer's snapshot ends earlier and reports
        // zeros for the fields it predates.
        let (shards_total, shards_down) =
            if version >= 2 { (self.u64()?, self.u64()?) } else { (0, 0) };
        let (hb_pings, hb_pongs, hb_timeouts) =
            if version >= 3 { (self.u64()?, self.u64()?, self.u64()?) } else { (0, 0, 0) };
        let auth_rejects = if version >= 4 { self.u64()? } else { 0 };
        // v5 appended the observability counters; older snapshots
        // report zeros (readers treat 0 uptime / 0 max as "unknown").
        let (uptime_ns, lat_overflow, lat_max_us) =
            if version >= 5 { (self.u64()?, self.u64()?, self.u64()?) } else { (0, 0, 0) };
        let mut kind_stats = [KindStats::default(); KIND_FAMILIES];
        if version >= 5 {
            for ks in kind_stats.iter_mut() {
                ks.submitted = self.u64()?;
                ks.completed = self.u64()?;
                ks.failed = self.u64()?;
            }
        }
        // v7 appended the list-scheduling packing counters; a pre-v7
        // peer's snapshot reads as all-serial (packing factor 1.0).
        let (plan_ops, plan_bundles) = if version >= 7 { (self.u64()?, self.u64()?) } else { (0, 0) };
        Ok(MetricsSnapshot {
            submitted,
            completed,
            failed,
            batches,
            batched_items,
            busy_ns,
            queue_depth,
            worker_health,
            lat_bins,
            lat_overflow,
            lat_max_us,
            uptime_ns,
            kind_stats,
            shards_total,
            shards_down,
            hb_pings,
            hb_pongs,
            hb_timeouts,
            auth_rejects,
            plan_ops,
            plan_bundles,
        })
    }

    fn event(&mut self) -> Result<Event> {
        let seq = self.u64()?;
        let shard = self.u32()?;
        let at_ns = self.u64()?;
        let tag = self.u8()?;
        let (a, b, cc) = (self.u64()?, self.u64()?, self.u64()?);
        let kind = EventKind::from_words(tag, a, b, cc)
            .ok_or_else(|| anyhow!("unknown event kind tag {tag}"))?;
        Ok(Event { seq, shard, at_ns, kind })
    }

    fn span(&mut self) -> Result<TraceSpan> {
        let trace = self.u64()?;
        let stage_byte = self.u8()?;
        let stage = Stage::from_u8(stage_byte)
            .ok_or_else(|| anyhow!("unknown trace stage {stage_byte}"))?;
        let start_ns = self.u64()?;
        let dur_ns = self.u64()?;
        Ok(TraceSpan { trace, stage, start_ns, dur_ns })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_roundtrip_and_layout() {
        let msg = Msg::Submit { id: 7, kind: FunctionKind::Mul(16), a: 123, b: 456, trace: 0 };
        let bytes = msg.to_bytes();
        assert_eq!(bytes[0], 1, "v1-expressible messages stay v1-labeled for old peers");
        assert_eq!(bytes[1], 1);
        assert_eq!(Msg::from_bytes(&bytes).unwrap(), msg);
        // A traced submit carries the trailing id and is v5-stamped.
        let traced =
            Msg::Submit { id: 7, kind: FunctionKind::Mul(16), a: 123, b: 456, trace: 0xBEEF };
        let tb = traced.to_bytes();
        assert_eq!(tb[0], 5, "traced submits need the v5 trailing field");
        assert_eq!(tb.len(), bytes.len() + 8);
        assert_eq!(Msg::from_bytes(&tb).unwrap(), traced);
        // Messages keep the lowest version label their layout allows.
        let reg = Msg::Register { name: "a".into(), addr: "b".into(), spare: false, prev: None };
        assert_eq!(reg.to_bytes()[0], 2, "a prev-less Register keeps the v2 layout");
        let reg3 =
            Msg::Register { name: "a".into(), addr: "b".into(), spare: false, prev: Some(4) };
        assert_eq!(reg3.to_bytes()[0], 3, "prev-carrying Register keeps the v3 layout");
        assert_eq!(
            Msg::MetricsReply(MetricsSnapshot::default()).to_bytes()[0],
            7,
            "the snapshot body carries the v7 trailing packing counters"
        );
        assert_eq!(Msg::Ping { nonce: 9 }.to_bytes()[0], 3, "heartbeats keep the v3 layout");
        assert_eq!(Msg::Pong { nonce: 9 }.to_bytes()[0], 3, "heartbeats keep the v3 layout");
        assert_eq!(Msg::Events { since: 0 }.to_bytes()[0], 5, "telemetry messages are v5");
        assert_eq!(Msg::SpansReq.to_bytes()[0], 5, "telemetry messages are v5");
        // An epoch-stamped EventsReply carries the trailing boot
        // epoch and is v6-stamped; an epoch-less one stays v5.
        let plain = Msg::EventsReply { latest: 4, events: vec![], boot_epoch: 0 };
        let pb = plain.to_bytes();
        assert_eq!(pb[0], 5, "epoch-less journal replies keep the v5 layout");
        let stamped = Msg::EventsReply { latest: 4, events: vec![], boot_epoch: 0xA11CE };
        let sb = stamped.to_bytes();
        assert_eq!(sb[0], 6, "epoch-stamped journal replies need the v6 trailing field");
        assert_eq!(sb.len(), pb.len() + 8);
        assert_eq!(Msg::from_bytes(&sb).unwrap(), stamped);
    }

    #[test]
    fn framing_roundtrip_over_a_byte_stream() {
        let msgs = vec![
            Msg::Submit { id: 1, kind: FunctionKind::Add(8), a: 2, b: 3, trace: 0 },
            Msg::Submit { id: 9, kind: FunctionKind::Xor(16), a: 4, b: 5, trace: 77 },
            Msg::Result { id: 1, value: 5, latency_us: 12, error: None },
            Msg::Result { id: 2, value: 0, latency_us: 9, error: Some("boom".into()) },
            Msg::MetricsReq,
            Msg::HealthReply { serving: true, workers: 4, routable: 3, retired: 1 },
            Msg::Shutdown,
            Msg::ShutdownAck,
            Msg::Register {
                name: "shard-a".into(),
                addr: "127.0.0.1:4870".into(),
                spare: true,
                prev: None,
            },
            Msg::Register {
                name: "shard-a".into(),
                addr: "127.0.0.1:4871".into(),
                spare: true,
                prev: Some(7),
            },
            Msg::Welcome { shard: 3, active: false },
            Msg::Ping { nonce: 0xDEAD },
            Msg::Pong { nonce: 0xDEAD },
            Msg::Events { since: 42 },
            Msg::EventsReply {
                latest: 3,
                events: vec![
                    Event {
                        seq: 1,
                        shard: 0,
                        at_ns: 123,
                        kind: EventKind::Scrub {
                            worker: 0,
                            corrected: 5,
                            detected: 1,
                            remapped: 1,
                        },
                    },
                    Event { seq: 2, shard: 1, at_ns: 456, kind: EventKind::AuthReject },
                ],
                boot_epoch: 0,
            },
            Msg::EventsReply {
                latest: 9,
                events: vec![Event {
                    seq: 8,
                    shard: 2,
                    at_ns: 789,
                    kind: EventKind::ShardRestarted { shard: 2, epoch: 0xFEED },
                }],
                boot_epoch: 0xFEED_F00D,
            },
            Msg::SpansReq,
            Msg::SpansReply {
                spans: vec![
                    TraceSpan { trace: 77, stage: Stage::RouterQueue, start_ns: 1, dur_ns: 2 },
                    TraceSpan { trace: 77, stage: Stage::Readback, start_ns: 9, dur_ns: 3 },
                ],
            },
        ];
        let mut stream = Vec::new();
        for m in &msgs {
            write_msg(&mut stream, m).unwrap();
        }
        let mut r: &[u8] = &stream;
        for m in &msgs {
            assert_eq!(&read_msg(&mut r).unwrap().expect("frame"), m);
        }
        assert!(read_msg(&mut r).unwrap().is_none(), "clean EOF after the last frame");
    }

    #[test]
    fn snapshot_roundtrip() {
        let snap = MetricsSnapshot {
            submitted: 10,
            completed: 8,
            failed: 2,
            batches: 3,
            batched_items: 10,
            busy_ns: 12345,
            queue_depth: 1,
            lat_bins: vec![0, 4, 3, 1],
            worker_health: vec![
                WorkerHealth { batches: 3, scrubs: 1, retired: true, ..Default::default() },
                WorkerHealth::default(),
            ],
            lat_overflow: 2,
            lat_max_us: 40_000_000,
            uptime_ns: 9_876_543_210,
            kind_stats: [
                KindStats { submitted: 5, completed: 4, failed: 1 },
                KindStats::default(),
                KindStats { submitted: 1, completed: 1, failed: 0 },
                KindStats::default(),
            ],
            shards_total: 3,
            shards_down: 1,
            hb_pings: 40,
            hb_pongs: 39,
            hb_timeouts: 1,
            auth_rejects: 2,
            plan_ops: 900,
            plan_bundles: 300,
        };
        let msg = Msg::MetricsReply(snap);
        assert_eq!(Msg::from_bytes(&msg.to_bytes()).unwrap(), msg);
    }

    #[test]
    fn old_version_frames_decode_compatibly() {
        // A v6 MetricsReply lacks the trailing packing counters (2
        // u64s), a v4 one also the observability counters (uptime +
        // histogram honesty + per-kind stats: 15 u64s), a v3 one also
        // the auth-reject counter, a v2 one also the heartbeat
        // counters, a v1 one also the membership counters: strip them
        // from a v7 encoding and relabel the version byte.
        let snap = MetricsSnapshot {
            completed: 9,
            lat_bins: vec![1, 2],
            shards_total: 2,
            shards_down: 1,
            hb_pings: 5,
            hb_pongs: 4,
            hb_timeouts: 1,
            auth_rejects: 3,
            uptime_ns: 777,
            lat_overflow: 1,
            lat_max_us: 123,
            plan_ops: 200,
            plan_bundles: 50,
            ..Default::default()
        };
        let mut v6 = Msg::MetricsReply(snap.clone()).to_bytes();
        v6.truncate(v6.len() - 16);
        v6[0] = 6;
        match Msg::from_bytes(&v6).unwrap() {
            Msg::MetricsReply(got) => {
                let expect = MetricsSnapshot { plan_ops: 0, plan_bundles: 0, ..snap.clone() };
                assert_eq!(got, expect, "v7 packing counters default to 0 for v6 peers")
            }
            other => panic!("unexpected decode: {other:?}"),
        }
        let snap = MetricsSnapshot { plan_ops: 0, plan_bundles: 0, ..snap };
        let mut v4 = Msg::MetricsReply(snap.clone()).to_bytes();
        v4.truncate(v4.len() - 136);
        v4[0] = 4;
        match Msg::from_bytes(&v4).unwrap() {
            Msg::MetricsReply(got) => {
                let expect = MetricsSnapshot {
                    uptime_ns: 0,
                    lat_overflow: 0,
                    lat_max_us: 0,
                    ..snap.clone()
                };
                assert_eq!(got, expect, "v5 observability fields default to 0 for v4 peers")
            }
            other => panic!("unexpected decode: {other:?}"),
        }
        let snap = MetricsSnapshot {
            uptime_ns: 0,
            lat_overflow: 0,
            lat_max_us: 0,
            auth_rejects: 0,
            ..snap
        };
        let mut v3 = Msg::MetricsReply(snap.clone()).to_bytes();
        v3.truncate(v3.len() - 144);
        v3[0] = 3;
        match Msg::from_bytes(&v3).unwrap() {
            Msg::MetricsReply(got) => {
                assert_eq!(got, snap, "auth-reject counter defaults to 0 for v3 peers")
            }
            other => panic!("unexpected decode: {other:?}"),
        }
        let snap = MetricsSnapshot { hb_pings: 0, hb_pongs: 0, hb_timeouts: 0, ..snap };
        let mut v2 = Msg::MetricsReply(snap.clone()).to_bytes();
        v2.truncate(v2.len() - 168);
        v2[0] = 2;
        match Msg::from_bytes(&v2).unwrap() {
            Msg::MetricsReply(got) => {
                assert_eq!(got, snap, "heartbeat counters default to 0 for v2 peers")
            }
            other => panic!("unexpected decode: {other:?}"),
        }
        let mut v1 = Msg::MetricsReply(snap.clone()).to_bytes();
        v1.truncate(v1.len() - 184);
        v1[0] = 1;
        match Msg::from_bytes(&v1).unwrap() {
            Msg::MetricsReply(got) => {
                let expect =
                    MetricsSnapshot { shards_total: 0, shards_down: 0, ..snap.clone() };
                assert_eq!(got, expect, "membership counters default to 0 for v1 peers")
            }
            other => panic!("unexpected decode: {other:?}"),
        }
        // Fixed-layout messages are identical across versions.
        let mut submit =
            Msg::Submit { id: 1, kind: FunctionKind::Add(8), a: 2, b: 3, trace: 0 }.to_bytes();
        submit[0] = 1;
        assert!(Msg::from_bytes(&submit).is_ok());
        // A traced submit relabeled v4 has trailing bytes the v4
        // layout cannot express: a clean error, not a misparse.
        let mut traced =
            Msg::Submit { id: 1, kind: FunctionKind::Add(8), a: 2, b: 3, trace: 9 }.to_bytes();
        traced[0] = 4;
        assert!(Msg::from_bytes(&traced).is_err(), "trace id requires wire v5");
        // v5-only types inside a v4 frame are rejected.
        let v5_only = [
            Msg::Events { since: 0 },
            Msg::EventsReply { latest: 0, events: vec![], boot_epoch: 0 },
            Msg::SpansReq,
            Msg::SpansReply { spans: vec![] },
        ];
        for m in v5_only {
            for v in [1u8, 4] {
                let mut bytes = m.to_bytes();
                bytes[0] = v;
                assert!(Msg::from_bytes(&bytes).is_err(), "{m:?} requires wire v5");
            }
        }
        // An epoch-stamped EventsReply relabeled v5 has trailing
        // bytes the v5 layout cannot express: a clean error, not a
        // misparse.
        let mut stamped =
            Msg::EventsReply { latest: 1, events: vec![], boot_epoch: 7 }.to_bytes();
        stamped[0] = 5;
        assert!(Msg::from_bytes(&stamped).is_err(), "boot epoch requires wire v6");
        // v2-only types inside a v1 frame are rejected.
        let mut reg = Msg::Register { name: "x".into(), addr: "y".into(), spare: false, prev: None }
            .to_bytes();
        reg[0] = 1;
        assert!(Msg::from_bytes(&reg).is_err(), "Register requires wire v2");
        // v3-only types inside a v2 frame are rejected.
        for m in [Msg::Ping { nonce: 1 }, Msg::Pong { nonce: 1 }] {
            for v in [1u8, 2] {
                let mut bytes = m.to_bytes();
                bytes[0] = v;
                assert!(Msg::from_bytes(&bytes).is_err(), "{m:?} requires wire v3");
            }
        }
        // A prev-carrying Register relabeled v2 has trailing bytes the
        // v2 layout cannot express: a clean error, not a misparse.
        let mut reg3 =
            Msg::Register { name: "x".into(), addr: "y".into(), spare: false, prev: Some(1) }
                .to_bytes();
        reg3[0] = 2;
        assert!(Msg::from_bytes(&reg3).is_err(), "prev index requires wire v3");
    }

    #[test]
    fn rejects_version_type_and_trailing_garbage() {
        let good = Msg::MetricsReq.to_bytes();
        let mut wrong_version = good.clone();
        wrong_version[0] = WIRE_VERSION + 1;
        assert!(Msg::from_bytes(&wrong_version).is_err());
        let mut wrong_type = good.clone();
        wrong_type[1] = 200;
        assert!(Msg::from_bytes(&wrong_type).is_err());
        let mut trailing = good;
        trailing.push(0);
        assert!(Msg::from_bytes(&trailing).is_err());
        assert!(Msg::from_bytes(&[]).is_err());
    }
}
