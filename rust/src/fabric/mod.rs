//! # fabric — sharded multi-mMPU serving over a wire protocol (§Scale).
//!
//! The paper's throughput story (and the fleet-scale ECC work of
//! arXiv:2105.04212) assumes many crossbar arrays operating in
//! parallel; a single in-process [`crate::coordinator::Coordinator`]
//! cannot express that. This subsystem turns one coordinator into one
//! *shard* of a fleet:
//!
//! * [`wire`] — a hand-rolled length-prefixed binary protocol
//!   (std `TcpListener`/`TcpStream` only; the offline vendor set has no
//!   serde/tokio) with versioned headers carrying
//!   submit / result / metrics / health / shutdown messages;
//! * [`FabricServer`] — a TCP front end over one coordinator per
//!   process (`remus fabric-serve`);
//! * [`Router`] — the client-side fan-out: FunctionKind-aware
//!   consistent hashing across a *dynamic* shard fleet (same-kind
//!   requests keep landing on the same shard, preserving dynamic
//!   batching), health-driven failover (capacity errors, disconnects
//!   and missed data-path heartbeats re-route in-flight requests to
//!   the next live shard — the wire-v3 `Ping`/`Pong` heartbeat is what
//!   catches *half-open* peers that accept writes but never reply), a
//!   supervisor that revives downed shards back into their stable ring
//!   slots, registration-based discovery (`Register`/`Welcome` frames
//!   instead of a static shard list; shards re-announce themselves
//!   periodically and remember their assigned slot, so a restarted
//!   *router* rebuilds the ring bit-identically), hot-spare shard
//!   pools promoted on failure and demoted on revival, and merged
//!   fleet metrics (stamped with `shards_total`/`shards_down` and the
//!   heartbeat counters) so reliability events — retirement,
//!   escalation, shard loss — are observable across processes;
//! * [`loadgen`] — the open-loop fleet load generator (`remus
//!   loadgen`): seeded Poisson arrivals at a fixed offered rate, a
//!   bounded in-flight window, golden-value verification, per-kind
//!   log-binned latency histograms, and a QPS sweep that locates the
//!   saturation knee (`BENCH_loadgen.json`);
//! * [`auth`] — mutual authentication and per-frame integrity
//!   (§Security, wire v4): a pre-shared-key handshake with per-
//!   connection ephemeral nonces, HKDF-style session-key derivation,
//!   and an authenticated stream seal (ChaCha20 + truncated
//!   HMAC-SHA256, implicit monotonic frame counters) wrapped around
//!   the plaintext codec. All hand-rolled from FIPS 180-4 / RFC 2104 /
//!   RFC 8439 primitives — the offline vendor set has no TLS — and
//!   enabled fleet-wide by `--psk-file`; without it the wire stays
//!   plaintext v3-compatible.
//!
//! **Telemetry** (§Telemetry, wire v5): submits optionally carry a
//! router-minted trace id (`--trace-sample`), shards answer
//! `Events{since}` / `SpansReq` control frames from their coordinator's
//! reliability journal and span ring, and the router merges per-shard
//! journals into one causally ordered fleet timeline
//! ([`Router::fleet_events`]) and collects fleet-wide stage spans
//! ([`Router::fleet_spans`]) for `remus top` / `remus trace`.
//!
//! **Flight recorder** (§Observability, wire v6): every role mints a
//! random non-zero *boot epoch* at startup and stamps it into its
//! `EventsReply` frames, so the router can tell a restarted shard
//! (journal sequence numbers restarted at 0) from a quiet one — it
//! resets the slot's cursor and synthesizes a `ShardRestarted` event
//! instead of stalling. With `--journal-dir` a background
//! [`crate::telemetry::WalFlusher`] spills the journal into a
//! checksummed, segment-rotated WAL that `remus postmortem`
//! reconstructs after a crash; `--metrics-addr` serves the Prometheus
//! text exposition over [`metrics_http`].
//!
//! **Data planes** (§Scale, `--data-plane`): every fabric data
//! connection rides one of two transports. `threads` is the original
//! blocking thread-per-connection pair and remains the bit-exact
//! reference; `epoll` ([`reactor`]) multiplexes all connections onto a
//! single readiness loop with nonblocking sockets, incremental frame
//! decode, vectored/coalesced writes, and bounded per-connection
//! backpressure — same frames, same FIFO reply order, same rejection
//! semantics, selectable per process and overridable in tests via the
//! `REMUS_DATA_PLANE` environment variable.
//!
//! Both the in-process coordinator and the router implement
//! [`crate::coordinator::Submitter`], so every load path (the serve
//! example, `remus soak`, benches) runs unchanged on either. End-to-end
//! coverage lives in `rust/tests/integration_fabric.rs` (loopback
//! multi-shard runs, bit-identical to in-process execution) and
//! `rust/tests/prop_fabric_wire.rs` (codec round-trips and malformed-
//! frame rejection); `cargo bench --bench fabric` measures the sharded
//! loopback throughput (`BENCH_fabric.json`).

pub mod auth;
pub mod loadgen;
pub mod metrics_http;
pub mod reactor;
pub mod router;
pub mod server;
pub mod wire;

pub use auth::Psk;
pub use metrics_http::MetricsHttp;
pub use reactor::DataPlane;
pub use router::{
    fetch_events, fetch_events_auth, fetch_metrics, fetch_metrics_auth, fetch_spans,
    fetch_spans_auth, probe_health, probe_health_auth, shutdown_endpoint, shutdown_endpoint_auth,
    RouteOptions, Router, RouterConfig,
};
pub use server::{FabricServer, ServeOptions};
