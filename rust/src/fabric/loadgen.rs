//! Open-loop fleet load generation (§Scale, `remus loadgen`).
//!
//! Every load number in the repo before this module came from
//! *closed-loop* drivers (`drive_load`, the serve example, the
//! benches): N requests in flight, each completion immediately
//! replaced. Closed loops self-throttle — when the target saturates,
//! the offered rate silently drops to match, so queueing collapse
//! never shows up in the numbers. This generator is **open-loop**: it
//! offers requests on a seeded Poisson arrival schedule at a fixed
//! `--qps` regardless of completions (up to a bounded in-flight
//! window, the safety valve that keeps an overloaded run from
//! accumulating unbounded state), verifies every reply against
//! [`FunctionKind::reference`], and records per-kind log-binned
//! latency histograms. Sweeping the offered rate across points exposes
//! the *knee* — the highest rate the target sustains before latency
//! and backlog diverge — which is the end-to-end throughput cost of
//! the reliability machinery the paper quantifies per-mechanism.
//!
//! The generator drives any [`Submitter`] — the in-process coordinator
//! or one-or-more fabric routers — and is deterministic: the arrival
//! law and the request content come from two *independent* seeded PCG
//! streams, so the (kind, a, b) request stream is bit-identical across
//! QPS points of one sweep and across repeated runs with one seed
//! (unit-tested below). `remus loadgen` writes the sweep as
//! `BENCH_loadgen.json` (archived by CI next to the other bench
//! artifacts; see EXPERIMENTS.md §Scale).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::metrics::{log2_bin_us, log2_percentile_us};
use crate::coordinator::{MetricsSnapshot, RequestResult, Submitter};
use crate::fabric::auth::{derive_keys, Psk};
use crate::fabric::wire::Msg;
use crate::mmpu::FunctionKind;
use crate::util::rng::Pcg64;

/// Log2 latency bins: bin i counts latencies in `[2^i, 2^(i+1))`
/// microseconds. 32 bins reach ~71 minutes — far past any latency an
/// overloaded sweep point can produce before its window stalls.
pub const HIST_BINS: usize = 32;

/// A run sustains its offered rate when it achieves at least this
/// fraction of it; the knee is the highest sustained point of a sweep.
pub const KNEE_SUSTAIN: f64 = 0.9;

/// Log-binned latency histogram with an exact maximum. The bin math is
/// a monoid (associative merge, [`LatencyHisto::default`] identity) so
/// per-kind, per-shard and per-point histograms can be folded in any
/// grouping — unit-tested below.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LatencyHisto {
    bins: [u64; HIST_BINS],
    max_us: u64,
}

impl LatencyHisto {
    pub fn record_us(&mut self, us: u64) {
        self.bins[log2_bin_us(us, HIST_BINS)] += 1;
        self.max_us = self.max_us.max(us.max(1));
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHisto) {
        for (b, o) in self.bins.iter_mut().zip(&other.bins) {
            *b += o;
        }
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.bins.iter().sum()
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile (upper bin edge, microseconds); 0 when
    /// empty. Delegates to the coordinator metrics' estimator
    /// ([`log2_percentile_us`]) so loadgen percentiles are directly
    /// comparable with the fleet snapshot's.
    pub fn percentile_us(&self, pct: f64) -> u64 {
        log2_percentile_us(&self.bins, pct)
    }
}

/// One sweep-point configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Offered arrival rate (Poisson mean), requests per second.
    pub qps: f64,
    /// Requests per run (the schedule length).
    pub requests: u64,
    /// Seed for both generator streams.
    pub seed: u64,
    /// In-flight cap: the generator blocks once this many requests are
    /// outstanding (counted as [`RunReport::window_stalls`] — a stalled
    /// run has degenerated to closed-loop and its point is past the
    /// knee by construction).
    pub window: usize,
    /// Request kinds, drawn uniformly per request.
    pub kinds: Vec<FunctionKind>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            qps: 2000.0,
            requests: 8192,
            seed: 0x10AD,
            window: 1024,
            kinds: vec![FunctionKind::Add(8), FunctionKind::Xor(16), FunctionKind::Mul(8)],
        }
    }
}

/// One scheduled request: when (relative to the run start) and what.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScheduledReq {
    pub at_ns: u64,
    pub kind: FunctionKind,
    pub a: u64,
    pub b: u64,
}

/// Build the deterministic arrival/request schedule. Arrival gaps are
/// exponential with mean `1/qps` (inverse-CDF over one PCG stream), so
/// arrivals are a Poisson process; kinds and operands come from a
/// *second* independent stream, which makes the (kind, a, b) sequence
/// a function of the seed alone — bit-identical across the QPS points
/// of a sweep, so every point offers the same work.
pub fn schedule(cfg: &LoadgenConfig) -> Vec<ScheduledReq> {
    assert!(cfg.qps > 0.0, "loadgen qps must be positive (got {})", cfg.qps);
    assert!(!cfg.kinds.is_empty(), "loadgen needs at least one kind");
    let mut arrivals = Pcg64::new(cfg.seed, 0xA441);
    let mut content = Pcg64::new(cfg.seed, 0xC0DE);
    let mut at_s = 0.0f64;
    (0..cfg.requests)
        .map(|_| {
            let u = (1.0 - arrivals.next_f64()).max(f64::MIN_POSITIVE);
            at_s += -u.ln() / cfg.qps;
            let kind = cfg.kinds[content.below(cfg.kinds.len() as u64) as usize];
            let a = content.below(251);
            let b = content.below(251);
            ScheduledReq { at_ns: (at_s * 1e9) as u64, kind, a, b }
        })
        .collect()
}

/// Per-kind outcome of one run.
#[derive(Clone, Debug, Default)]
pub struct KindReport {
    pub hist: LatencyHisto,
    /// Replies whose value matched [`FunctionKind::reference`].
    pub ok: u64,
    /// Replies with a wrong value — an uncorrected error escaping.
    pub wrong: u64,
    /// Explicit error results (or dropped reply channels).
    pub errors: u64,
}

/// Outcome of one open-loop run at a fixed offered rate.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub offered_qps: f64,
    pub achieved_qps: f64,
    pub requests: u64,
    pub ok: u64,
    pub wrong: u64,
    pub errors: u64,
    /// Times the generator found the in-flight window full and had to
    /// block — each one a departure from open-loop arrivals.
    pub window_stalls: u64,
    pub elapsed: Duration,
    /// Per-kind reports, aligned with the config's `kinds`.
    pub kinds: Vec<(FunctionKind, KindReport)>,
}

impl RunReport {
    /// Did this point sustain its offered rate (the knee criterion)?
    pub fn sustained(&self) -> bool {
        self.achieved_qps >= KNEE_SUSTAIN * self.offered_qps
    }
}

/// Sleep (coarsely), then yield (finely), until `target`.
fn pace_until(target: Instant) {
    loop {
        let now = Instant::now();
        if now >= target {
            return;
        }
        let left = target - now;
        if left > Duration::from_micros(500) {
            std::thread::sleep(left - Duration::from_micros(300));
        } else {
            std::thread::yield_now();
        }
    }
}

/// Execute one open-loop run: pace the schedule against the submitter,
/// collect and verify every reply on a companion thread, and fold the
/// per-request latencies (as measured by the serving side — the
/// coordinator's completion clock in-process, the router's
/// submit-to-reply clock over the fabric) into per-kind histograms.
pub fn run(sub: &dyn Submitter, cfg: &LoadgenConfig) -> RunReport {
    let sched = schedule(cfg);
    let window = cfg.window.max(1);
    let kinds = cfg.kinds.clone();
    let mut window_stalls = 0u64;
    let t0 = Instant::now();
    type InFlight = (usize, u64, u64, Receiver<RequestResult>);
    let (tx, rx) = sync_channel::<InFlight>(window);
    let per_kind: Vec<KindReport> = std::thread::scope(|s| {
        let collector = {
            let kinds = kinds.clone();
            s.spawn(move || {
                let mut stats = vec![KindReport::default(); kinds.len()];
                while let Ok((ki, a, b, result_rx)) = rx.recv() {
                    let stat = &mut stats[ki];
                    match result_rx.recv() {
                        Ok(r) if r.is_ok() => {
                            if r.value == kinds[ki].reference(a, b) {
                                stat.ok += 1;
                            } else {
                                stat.wrong += 1;
                            }
                            stat.hist.record_us(r.latency.as_micros() as u64);
                        }
                        _ => stat.errors += 1,
                    }
                }
                stats
            })
        };
        for req in &sched {
            pace_until(t0 + Duration::from_nanos(req.at_ns));
            let ki = kinds.iter().position(|k| *k == req.kind).expect("kind from own schedule");
            let item = (ki, req.a, req.b, sub.submit(req.kind, req.a, req.b));
            match tx.try_send(item) {
                Ok(()) => {}
                Err(TrySendError::Full(item)) => {
                    // Window saturated: block (closed-loop from here
                    // until the backlog drains) and count the departure.
                    window_stalls += 1;
                    if tx.send(item).is_err() {
                        break;
                    }
                }
                Err(TrySendError::Disconnected(_)) => break,
            }
        }
        drop(tx);
        collector.join().expect("loadgen collector panicked")
    });
    let elapsed = t0.elapsed();
    let (ok, wrong, errors) = per_kind.iter().fold((0, 0, 0), |(o, w, e), k| {
        (o + k.ok, w + k.wrong, e + k.errors)
    });
    RunReport {
        offered_qps: cfg.qps,
        achieved_qps: sched.len() as f64 / elapsed.as_secs_f64(),
        requests: sched.len() as u64,
        ok,
        wrong,
        errors,
        window_stalls,
        elapsed,
        kinds: kinds.into_iter().zip(per_kind).collect(),
    }
}

/// A full QPS sweep and its knee.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub points: Vec<RunReport>,
    /// Highest offered rate that was sustained
    /// ([`RunReport::sustained`]); `None` when every point collapsed.
    pub knee_qps: Option<f64>,
}

/// The knee of a sweep: the highest offered rate that was sustained
/// ([`RunReport::sustained`]), `None` when every point collapsed.
pub fn knee(points: &[RunReport]) -> Option<f64> {
    points
        .iter()
        .filter(|p| p.sustained())
        .map(|p| p.offered_qps)
        .fold(None, |acc: Option<f64>, q| Some(acc.map_or(q, |a| a.max(q))))
}

/// Run the schedule at each offered rate (ascending order recommended)
/// and locate the knee.
pub fn sweep(sub: &dyn Submitter, base: &LoadgenConfig, qps_points: &[f64]) -> SweepReport {
    let points: Vec<RunReport> = qps_points
        .iter()
        .map(|&qps| run(sub, &LoadgenConfig { qps, ..base.clone() }))
        .collect();
    let knee_qps = knee(&points);
    SweepReport { points, knee_qps }
}

/// Round-robin fan-out over N independent connections to one fleet:
/// each submit goes to the next inner [`Submitter`] in turn. `remus
/// loadgen --connections` models N concurrent clients with one
/// [`crate::fabric::Router`] per slot, so the serving side carries N
/// real data connections (its per-connection threads or reactor
/// registrations), not one multiplexed session — the connection count
/// is what the §Scale knee-vs-connections sweep varies.
pub struct MultiConn<S: Submitter> {
    subs: Vec<S>,
    next: AtomicUsize,
}

impl<S: Submitter> MultiConn<S> {
    /// Fan out over `subs` (at least one).
    pub fn new(subs: Vec<S>) -> Self {
        assert!(!subs.is_empty(), "MultiConn needs at least one connection");
        Self { subs, next: AtomicUsize::new(0) }
    }

    /// The number of fanned-out connections.
    pub fn connections(&self) -> usize {
        self.subs.len()
    }

    /// Take the inner submitters back (to shut them down).
    pub fn into_inner(self) -> Vec<S> {
        self.subs
    }
}

impl<S: Submitter> Submitter for MultiConn<S> {
    fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.subs.len();
        self.subs[i].submit(kind, a, b)
    }

    /// The fleet view through the first connection — every connection
    /// reaches the same shards, so any of them is representative.
    fn metrics(&self) -> MetricsSnapshot {
        self.subs[0].metrics()
    }

    fn is_serving(&self) -> bool {
        self.subs.iter().any(|s| s.is_serving())
    }
}

/// One connection count of a knee-vs-connections sweep: the full QPS
/// sweep that was run at this fan-out, and its knee.
#[derive(Clone, Debug)]
pub struct ConnPoint {
    pub connections: usize,
    pub points: Vec<RunReport>,
    pub knee_qps: Option<f64>,
}

/// A knee-vs-connections sweep under one data plane (§Scale,
/// `--data-plane`): the same QPS sweep repeated at each connection
/// count, so the artifact shows where each plane's knee moves as
/// per-connection serving state multiplies.
#[derive(Clone, Debug)]
pub struct ConnSweepReport {
    /// The data plane the serving side ran (`"threads"` / `"epoll"`).
    pub plane: String,
    pub points: Vec<ConnPoint>,
}

impl ConnSweepReport {
    /// The knee at a given connection count, when that count was swept
    /// and sustained at all.
    pub fn knee_at(&self, connections: usize) -> Option<f64> {
        self.points.iter().find(|p| p.connections == connections).and_then(|p| p.knee_qps)
    }
}

/// Sealed-vs-plaintext frame-processing cost (§Security): CPU time per
/// frame through the wire codec alone vs the codec plus the
/// authenticated seal. Purely informational — it bounds the per-frame
/// crypto tax independent of network and batching effects, which
/// dominate end-to-end latency.
#[derive(Clone, Debug)]
pub struct SealOverhead {
    /// Frames measured per arm.
    pub frames: u64,
    /// Mean encode+decode nanoseconds per plaintext frame.
    pub plain_ns_per_frame: f64,
    /// Mean encode+seal+open+decode nanoseconds per sealed frame.
    pub sealed_ns_per_frame: f64,
    /// `(sealed - plain) / plain`, percent.
    pub overhead_pct: f64,
}

/// Measure [`SealOverhead`] over a representative request/reply mix
/// (`Submit` and `Result` frames — the data-path hot loop). Both arms
/// run the same codec work; the sealed arm adds one `seal` + one
/// `open` per frame with session keys derived from a throwaway PSK.
pub fn measure_seal_overhead(frames: u64) -> SealOverhead {
    let msgs = [
        Msg::Submit { id: 7, kind: FunctionKind::Mul(8), a: 113, b: 223, trace: 0 },
        Msg::Result { id: 7, value: 25199, latency_us: 180, error: None },
    ];
    let psk = Psk::from_material(b"loadgen seal-overhead probe").expect("static material");
    let keys = derive_keys(&psk, &[0x11; 32], &[0x22; 32]);
    let (mut tx, mut rx) = (keys.c2s.clone(), keys.c2s);
    let mut sink = 0u64;
    let t0 = Instant::now();
    for i in 0..frames {
        let bytes = msgs[(i % 2) as usize].to_bytes();
        let msg = Msg::from_bytes(&bytes).expect("own encoding");
        sink = sink.wrapping_add(bytes.len() as u64 + msg.to_bytes()[0] as u64);
    }
    let plain = t0.elapsed();
    let t1 = Instant::now();
    for i in 0..frames {
        let sealed = tx.seal(&msgs[(i % 2) as usize].to_bytes());
        let bytes = rx.open(&sealed).expect("own seal");
        let msg = Msg::from_bytes(&bytes).expect("own encoding");
        sink = sink.wrapping_add(sealed.len() as u64 + msg.to_bytes()[0] as u64);
    }
    let sealed = t1.elapsed();
    std::hint::black_box(sink);
    let frames_f = frames.max(1) as f64;
    let plain_ns = plain.as_nanos() as f64 / frames_f;
    let sealed_ns = sealed.as_nanos() as f64 / frames_f;
    SealOverhead {
        frames,
        plain_ns_per_frame: plain_ns,
        sealed_ns_per_frame: sealed_ns,
        overhead_pct: if plain_ns > 0.0 { (sealed_ns - plain_ns) / plain_ns * 100.0 } else { 0.0 },
    }
}

/// Telemetry hot-path cost (§Telemetry): per-request CPU time through
/// the data-path frame work alone, with a *disabled* tracer (sample 0
/// — the single-branch path every untraced request pays), and with
/// 1-in-64 sampling (mint + sample check + span recording). Purely
/// informational, like [`SealOverhead`]: it bounds the per-request
/// telemetry tax independent of network and batching effects. The
/// acceptance bar is that the disabled arm stays within measurement
/// noise of the baseline.
#[derive(Clone, Debug)]
pub struct TelemetryOverhead {
    /// Requests measured per arm.
    pub requests: u64,
    /// Mean nanoseconds per request with no tracer at all.
    pub baseline_ns_per_req: f64,
    /// Mean nanoseconds per request with a disabled tracer (sample 0).
    pub disabled_ns_per_req: f64,
    /// Mean nanoseconds per request at 1-in-64 sampling.
    pub sampled_ns_per_req: f64,
    /// `(disabled - baseline) / baseline`, percent (noise-level).
    pub disabled_overhead_pct: f64,
    /// `(sampled - baseline) / baseline`, percent.
    pub sampled_overhead_pct: f64,
}

/// Sampling rate of the measured arm in [`measure_telemetry_overhead`].
pub const TELEMETRY_PROBE_SAMPLE: u64 = 64;

/// Measure [`TelemetryOverhead`] over the data-path hot loop: every
/// arm encodes and decodes one `Submit` frame per request (the real
/// per-request wire work); the tracer arms add exactly what the router
/// adds — a mint, a sample check, and (when sampled) two span records.
pub fn measure_telemetry_overhead(requests: u64) -> TelemetryOverhead {
    use crate::telemetry::{Stage, Tracer, DEFAULT_SPAN_CAPACITY};
    fn frame_work(trace: u64, sink: &mut u64) {
        let msg = Msg::Submit { id: 7, kind: FunctionKind::Mul(8), a: 113, b: 223, trace };
        let bytes = msg.to_bytes();
        let back = Msg::from_bytes(&bytes).expect("own encoding");
        *sink = sink.wrapping_add(bytes.len() as u64 + matches!(back, Msg::Submit { .. }) as u64);
    }
    let mut sink = 0u64;
    let t0 = Instant::now();
    for _ in 0..requests {
        frame_work(0, &mut sink);
    }
    let baseline = t0.elapsed();
    let off = Tracer::new(0, DEFAULT_SPAN_CAPACITY);
    let t1 = Instant::now();
    for _ in 0..requests {
        let trace = off.mint();
        frame_work(trace, &mut sink);
        if off.sampled(trace) {
            off.record(trace, Stage::RouterQueue, 0, 1);
            off.record(trace, Stage::WireTransit, 1, 1);
        }
    }
    let disabled = t1.elapsed();
    let on = Tracer::new(TELEMETRY_PROBE_SAMPLE, DEFAULT_SPAN_CAPACITY);
    let t2 = Instant::now();
    for _ in 0..requests {
        let trace = on.mint();
        frame_work(trace, &mut sink);
        if on.sampled(trace) {
            on.record(trace, Stage::RouterQueue, 0, 1);
            on.record(trace, Stage::WireTransit, 1, 1);
        }
    }
    let sampled = t2.elapsed();
    std::hint::black_box(sink);
    let n = requests.max(1) as f64;
    let base_ns = baseline.as_nanos() as f64 / n;
    let off_ns = disabled.as_nanos() as f64 / n;
    let on_ns = sampled.as_nanos() as f64 / n;
    let pct = |arm: f64| {
        if base_ns > 0.0 {
            (arm - base_ns) / base_ns * 100.0
        } else {
            0.0
        }
    };
    TelemetryOverhead {
        requests,
        baseline_ns_per_req: base_ns,
        disabled_ns_per_req: off_ns,
        sampled_ns_per_req: on_ns,
        disabled_overhead_pct: pct(off_ns),
        sampled_overhead_pct: pct(on_ns),
    }
}

/// Journal persistence cost (§Observability): per-event CPU time
/// through the reliability journal's record + cursor-drain loop with
/// no WAL at all, with buffered WAL appends (the `--journal-dir`
/// default), and with an fsync after every batch. Purely
/// informational, like [`SealOverhead`]: the flusher runs off the hot
/// path, so this bounds the *flusher thread's* cost per event, not a
/// request-path tax — the acceptance bar is that the buffered arm
/// stays cheap enough for any plausible event rate.
#[derive(Clone, Debug)]
pub struct JournalPersistenceOverhead {
    /// Events recorded and drained per arm.
    pub events: u64,
    /// Mean nanoseconds per event with no WAL (journal ring only).
    pub off_ns_per_event: f64,
    /// Mean nanoseconds per event with buffered WAL appends.
    pub buffered_ns_per_event: f64,
    /// Mean nanoseconds per event with an fsync per drained batch.
    pub fsync_ns_per_event: f64,
    /// `(buffered - off) / off`, percent.
    pub buffered_overhead_pct: f64,
    /// `(fsync - off) / off`, percent.
    pub fsync_overhead_pct: f64,
}

/// Events drained per WAL append in [`measure_journal_overhead`] — the
/// batch shape a busy flusher tick sees.
pub const JOURNAL_PROBE_BATCH: u64 = 64;

/// Measure [`JournalPersistenceOverhead`]: every arm records `events`
/// reliability events and drains them in [`JOURNAL_PROBE_BATCH`]-sized
/// batches through a journal cursor (exactly the flusher's loop); the
/// WAL arms additionally append each drained batch to a real segment
/// file in a throwaway temp directory, buffered or fsynced per batch.
pub fn measure_journal_overhead(events: u64) -> Result<JournalPersistenceOverhead> {
    use crate::telemetry::{EventJournal, EventKind, FsyncMode, WalConfig, WalWriter};

    fn run_arm(events: u64, mut wal: Option<WalWriter>) -> Result<Duration> {
        // Capacity past the batch size so no event is overwritten
        // between drains.
        let journal = EventJournal::new(4 * JOURNAL_PROBE_BATCH as usize);
        let mut cursor = 0u64;
        let t0 = Instant::now();
        for i in 0..events {
            journal.record(EventKind::Scrub {
                worker: (i % 7) as u32,
                corrected: i % 3,
                detected: (i % 5) as u32,
                remapped: 0,
            });
            if (i + 1) % JOURNAL_PROBE_BATCH == 0 {
                let (batch, next) = journal.since(cursor);
                cursor = next;
                if let Some(w) = wal.as_mut() {
                    w.append_batch(&batch).context("WAL append during overhead probe")?;
                }
            }
        }
        let (tail, _) = journal.since(cursor);
        if let Some(w) = wal.as_mut() {
            w.append_batch(&tail).context("WAL final append during overhead probe")?;
        }
        Ok(t0.elapsed())
    }

    let off = run_arm(events, None)?;
    let timed_wal_arm = |tag: &str, fsync: FsyncMode| -> Result<Duration> {
        let dir = std::env::temp_dir()
            .join(format!("remus_wal_probe_{}_{tag}", std::process::id()));
        let cfg = WalConfig { fsync, ..WalConfig::default() };
        let writer = WalWriter::create(&dir, crate::telemetry::mint_boot_epoch(), cfg)
            .with_context(|| format!("opening probe WAL in {}", dir.display()))?;
        let elapsed = run_arm(events, Some(writer));
        let _ = std::fs::remove_dir_all(&dir);
        elapsed
    };
    let buffered = timed_wal_arm("buffered", FsyncMode::Buffered)?;
    let fsynced = timed_wal_arm("fsync", FsyncMode::PerBatch)?;
    let n = events.max(1) as f64;
    let off_ns = off.as_nanos() as f64 / n;
    let buf_ns = buffered.as_nanos() as f64 / n;
    let sync_ns = fsynced.as_nanos() as f64 / n;
    let pct = |arm: f64| if off_ns > 0.0 { (arm - off_ns) / off_ns * 100.0 } else { 0.0 };
    Ok(JournalPersistenceOverhead {
        events,
        off_ns_per_event: off_ns,
        buffered_ns_per_event: buf_ns,
        fsync_ns_per_event: sync_ns,
        buffered_overhead_pct: pct(buf_ns),
        fsync_overhead_pct: pct(sync_ns),
    })
}

/// Write a sweep as machine-readable JSON (the `BENCH_loadgen.json`
/// artifact CI archives; hand-rolled like `bench_harness` — serde is
/// not in the offline vendor set). `seal` adds the informational
/// sealed-vs-plaintext frame cost row (`"seal_overhead"`), `telemetry`
/// the disabled-vs-sampled tracing cost row (`"telemetry_overhead"`),
/// `journal` the WAL-off/buffered/fsync persistence cost row
/// (`"journal_persistence_overhead"`); each is `null` when not
/// measured.
pub fn write_json(
    path: &str,
    cfg: &LoadgenConfig,
    sweep: &SweepReport,
    seal: Option<&SealOverhead>,
    telemetry: Option<&TelemetryOverhead>,
    journal: Option<&JournalPersistenceOverhead>,
) -> Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"loadgen\",\n");
    out.push_str(&format!("  \"seed\": {},\n", cfg.seed));
    out.push_str(&format!("  \"window\": {},\n", cfg.window));
    out.push_str(&format!("  \"requests_per_point\": {},\n", cfg.requests));
    match sweep.knee_qps {
        Some(q) => out.push_str(&format!("  \"knee_qps\": {q:.1},\n")),
        None => out.push_str("  \"knee_qps\": null,\n"),
    }
    match seal {
        Some(s) => out.push_str(&format!(
            "  \"seal_overhead\": {{\"frames\": {}, \"plain_ns_per_frame\": {:.1}, \
             \"sealed_ns_per_frame\": {:.1}, \"overhead_pct\": {:.1}}},\n",
            s.frames, s.plain_ns_per_frame, s.sealed_ns_per_frame, s.overhead_pct
        )),
        None => out.push_str("  \"seal_overhead\": null,\n"),
    }
    match telemetry {
        Some(t) => out.push_str(&format!(
            "  \"telemetry_overhead\": {{\"requests\": {}, \"baseline_ns_per_req\": {:.1}, \
             \"disabled_ns_per_req\": {:.1}, \"sampled_ns_per_req\": {:.1}, \
             \"disabled_overhead_pct\": {:.1}, \"sampled_overhead_pct\": {:.1}}},\n",
            t.requests,
            t.baseline_ns_per_req,
            t.disabled_ns_per_req,
            t.sampled_ns_per_req,
            t.disabled_overhead_pct,
            t.sampled_overhead_pct
        )),
        None => out.push_str("  \"telemetry_overhead\": null,\n"),
    }
    match journal {
        Some(j) => out.push_str(&format!(
            "  \"journal_persistence_overhead\": {{\"events\": {}, \
             \"off_ns_per_event\": {:.1}, \"buffered_ns_per_event\": {:.1}, \
             \"fsync_ns_per_event\": {:.1}, \"buffered_overhead_pct\": {:.1}, \
             \"fsync_overhead_pct\": {:.1}}},\n",
            j.events,
            j.off_ns_per_event,
            j.buffered_ns_per_event,
            j.fsync_ns_per_event,
            j.buffered_overhead_pct,
            j.fsync_overhead_pct
        )),
        None => out.push_str("  \"journal_persistence_overhead\": null,\n"),
    }
    out.push_str("  \"points\": [\n");
    for (i, p) in sweep.points.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"qps_offered\": {:.1}, \"qps_achieved\": {:.1}, \"sustained\": {}, \
             \"requests\": {}, \"ok\": {}, \"wrong\": {}, \"errors\": {}, \
             \"window_stalls\": {}, \"elapsed_s\": {:.3}, \"kinds\": [",
            p.offered_qps,
            p.achieved_qps,
            p.sustained(),
            p.requests,
            p.ok,
            p.wrong,
            p.errors,
            p.window_stalls,
            p.elapsed.as_secs_f64()
        ));
        for (j, (kind, k)) in p.kinds.iter().enumerate() {
            out.push_str(&format!(
                "{{\"kind\": \"{}\", \"count\": {}, \"p50_us\": {}, \"p90_us\": {}, \
                 \"p99_us\": {}, \"max_us\": {}}}",
                kind.name(),
                k.hist.count(),
                k.hist.percentile_us(50.0),
                k.hist.percentile_us(90.0),
                k.hist.percentile_us(99.0),
                k.hist.max_us()
            ));
            if j + 1 < p.kinds.len() {
                out.push_str(", ");
            }
        }
        out.push_str("]}");
        out.push_str(if i + 1 < sweep.points.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
    Ok(())
}

/// Write a knee-vs-connections sweep (both planes of one run) as
/// machine-readable JSON — the `BENCH_loadgen_epoll.json` artifact CI
/// archives and gates on (epoll knee at 64 connections must be at
/// least the threads knee measured in the same run). Hand-rolled like
/// [`write_json`].
pub fn write_connections_json(
    path: &str,
    cfg: &LoadgenConfig,
    qps_points: &[f64],
    planes: &[ConnSweepReport],
) -> Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"loadgen_connections\",\n");
    out.push_str(&format!("  \"seed\": {},\n", cfg.seed));
    out.push_str(&format!("  \"window\": {},\n", cfg.window));
    out.push_str(&format!("  \"requests_per_point\": {},\n", cfg.requests));
    let qps: Vec<String> = qps_points.iter().map(|q| format!("{q:.1}")).collect();
    out.push_str(&format!("  \"qps_points\": [{}],\n", qps.join(", ")));
    out.push_str("  \"planes\": [\n");
    for (pi, plane) in planes.iter().enumerate() {
        out.push_str(&format!("    {{\"plane\": \"{}\", \"points\": [\n", plane.plane));
        for (ci, cp) in plane.points.iter().enumerate() {
            let knee = match cp.knee_qps {
                Some(q) => format!("{q:.1}"),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "      {{\"connections\": {}, \"knee_qps\": {knee}, \"runs\": [",
                cp.connections
            ));
            for (ri, r) in cp.points.iter().enumerate() {
                let mut hist = LatencyHisto::default();
                for (_, k) in &r.kinds {
                    hist.merge(&k.hist);
                }
                out.push_str(&format!(
                    "{{\"qps_offered\": {:.1}, \"qps_achieved\": {:.1}, \"sustained\": {}, \
                     \"ok\": {}, \"wrong\": {}, \"errors\": {}, \"window_stalls\": {}, \
                     \"p50_us\": {}, \"p99_us\": {}}}",
                    r.offered_qps,
                    r.achieved_qps,
                    r.sustained(),
                    r.ok,
                    r.wrong,
                    r.errors,
                    r.window_stalls,
                    hist.percentile_us(50.0),
                    hist.percentile_us(99.0)
                ));
                if ri + 1 < cp.points.len() {
                    out.push_str(", ");
                }
            }
            out.push_str("]}");
            out.push_str(if ci + 1 < plane.points.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ]}");
        out.push_str(if pi + 1 < planes.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, CoordinatorConfig};

    fn cfg(qps: f64, requests: u64, seed: u64) -> LoadgenConfig {
        LoadgenConfig { qps, requests, seed, ..Default::default() }
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let a = schedule(&cfg(1000.0, 500, 7));
        let b = schedule(&cfg(1000.0, 500, 7));
        assert_eq!(a, b, "same seed + qps must reproduce the stream bit for bit");
        let c = schedule(&cfg(1000.0, 500, 8));
        assert_ne!(a, c, "a different seed must move the stream");
    }

    #[test]
    fn request_content_is_invariant_across_qps() {
        // The arrival and content streams are independent: changing the
        // offered rate re-times the same requests, so every sweep point
        // offers identical work.
        let slow = schedule(&cfg(500.0, 400, 7));
        let fast = schedule(&cfg(4000.0, 400, 7));
        for (s, f) in slow.iter().zip(&fast) {
            assert_eq!((s.kind, s.a, s.b), (f.kind, f.a, f.b));
        }
        assert!(
            slow.last().unwrap().at_ns > 4 * fast.last().unwrap().at_ns,
            "an 8x slower rate must stretch the schedule"
        );
    }

    #[test]
    fn arrival_gaps_are_exponential_with_the_offered_mean() {
        let qps = 2000.0;
        let sched = schedule(&cfg(qps, 20_000, 11));
        let mean_gap_ns = sched.last().unwrap().at_ns as f64 / sched.len() as f64;
        let expect = 1e9 / qps;
        assert!(
            (mean_gap_ns - expect).abs() < expect * 0.05,
            "mean gap {mean_gap_ns:.0}ns vs expected {expect:.0}ns"
        );
        // Poisson arrivals are bursty: a meaningful fraction of gaps is
        // under a quarter of the mean (a uniform pacer would have none).
        let short = sched
            .windows(2)
            .filter(|w| ((w[1].at_ns - w[0].at_ns) as f64) < expect * 0.25)
            .count();
        assert!(short > sched.len() / 10, "only {short} short gaps — not Poisson");
    }

    #[test]
    fn histogram_merge_is_associative_and_has_identity() {
        use crate::testutil::prop::Cases;
        Cases::new(128).run(|g| {
            let mut hs: Vec<LatencyHisto> = (0..3).map(|_| LatencyHisto::default()).collect();
            for h in hs.iter_mut() {
                for _ in 0..g.usize_in(0..=64) {
                    h.record_us(g.u64_in(0..=2_000_000));
                }
            }
            // (a + b) + c == a + (b + c)
            let mut left = hs[0].clone();
            left.merge(&hs[1]);
            left.merge(&hs[2]);
            let mut bc = hs[1].clone();
            bc.merge(&hs[2]);
            let mut right = hs[0].clone();
            right.merge(&bc);
            assert_eq!(left, right, "merge must be associative");
            // a + b == b + a, and the default histogram is the identity.
            let mut ab = hs[0].clone();
            ab.merge(&hs[1]);
            let mut ba = hs[1].clone();
            ba.merge(&hs[0]);
            assert_eq!(ab, ba, "merge must be commutative");
            let mut with_id = hs[0].clone();
            with_id.merge(&LatencyHisto::default());
            assert_eq!(with_id, hs[0], "default must be the merge identity");
            assert_eq!(left.count(), hs.iter().map(|h| h.count()).sum::<u64>());
        });
    }

    #[test]
    fn histogram_percentiles_bracket_the_data() {
        let mut h = LatencyHisto::default();
        for _ in 0..90 {
            h.record_us(10);
        }
        for _ in 0..10 {
            h.record_us(5000);
        }
        assert!(h.percentile_us(50.0) <= 32);
        assert!(h.percentile_us(99.0) >= 4096);
        assert_eq!(h.max_us(), 5000);
        assert_eq!(h.count(), 100);
        assert_eq!(LatencyHisto::default().percentile_us(99.0), 0);
    }

    #[test]
    fn open_loop_run_verifies_every_reply_against_the_oracle() {
        // Default rows/cols so every default kind (Mul(8) included)
        // fits the crossbar shape.
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            ..Default::default()
        })
        .unwrap();
        let cfg = LoadgenConfig { qps: 20_000.0, requests: 600, seed: 3, ..Default::default() };
        let rep = run(&coord, &cfg);
        coord.shutdown();
        assert_eq!(rep.requests, 600);
        assert_eq!(rep.ok, 600, "wrong={} errors={}", rep.wrong, rep.errors);
        assert_eq!(rep.wrong + rep.errors, 0);
        let hist_total: u64 = rep.kinds.iter().map(|(_, k)| k.hist.count()).sum();
        assert_eq!(hist_total, 600, "every ok reply lands in a histogram");
        assert!(rep.achieved_qps > 0.0);
        for (_, k) in &rep.kinds {
            assert!(k.hist.percentile_us(50.0) <= k.hist.percentile_us(99.0));
        }
    }

    #[test]
    fn knee_is_the_highest_sustained_point_and_json_is_written() {
        let mk = |offered: f64, achieved: f64| RunReport {
            offered_qps: offered,
            achieved_qps: achieved,
            requests: 10,
            ok: 10,
            wrong: 0,
            errors: 0,
            window_stalls: 0,
            elapsed: Duration::from_millis(5),
            kinds: vec![(FunctionKind::Add(8), KindReport::default())],
        };
        let points = vec![mk(1000.0, 995.0), mk(2000.0, 1950.0), mk(4000.0, 2500.0)];
        // The real knee computation (the one sweep() uses), not a copy.
        let knee_qps = knee(&points);
        assert_eq!(knee_qps, Some(2000.0), "4000 collapsed (62% of offered), 2000 sustained");
        assert_eq!(knee(&[mk(1000.0, 500.0)]), None, "a fully collapsed sweep has no knee");
        let sweep = SweepReport { points, knee_qps };
        let path = std::env::temp_dir().join("BENCH_loadgen_selftest.json");
        let path = path.to_str().unwrap().to_string();
        write_json(&path, &LoadgenConfig::default(), &sweep, None, None, None).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"loadgen\""));
        assert!(text.contains("\"knee_qps\": 2000.0"));
        assert!(text.contains("\"p99_us\""));
        assert!(text.contains("\"sustained\": false"));
        assert!(text.contains("\"seal_overhead\": null"));
        assert!(text.contains("\"telemetry_overhead\": null"));
        assert!(text.contains("\"journal_persistence_overhead\": null"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn multi_conn_round_robins_and_connections_json_is_written() {
        // Two in-process coordinators behind one MultiConn: the
        // round-robin must spread requests across both of them.
        let mk = || {
            Coordinator::start(CoordinatorConfig { workers: 1, ..Default::default() }).unwrap()
        };
        let multi = MultiConn::new(vec![mk(), mk()]);
        assert_eq!(multi.connections(), 2);
        assert!(multi.is_serving());
        let cfg = LoadgenConfig { qps: 50_000.0, requests: 64, seed: 5, ..Default::default() };
        let rep = run(&multi, &cfg);
        assert_eq!(rep.ok, 64, "wrong={} errors={}", rep.wrong, rep.errors);
        let counts: Vec<u64> = multi
            .into_inner()
            .into_iter()
            .map(|c| {
                let done = Submitter::metrics(&c).completed;
                c.shutdown();
                done
            })
            .collect();
        assert!(
            counts.iter().all(|&c| c > 0),
            "round-robin must hit every connection: {counts:?}"
        );
        let point = |conns: usize, knee: Option<f64>| ConnPoint {
            connections: conns,
            points: vec![rep.clone()],
            knee_qps: knee,
        };
        let planes = vec![
            ConnSweepReport {
                plane: "threads".into(),
                points: vec![point(1, Some(2000.0)), point(64, Some(4000.0))],
            },
            ConnSweepReport { plane: "epoll".into(), points: vec![point(64, None)] },
        ];
        assert_eq!(planes[0].knee_at(64), Some(4000.0));
        assert_eq!(planes[0].knee_at(8), None, "unswept counts have no knee");
        assert_eq!(planes[1].knee_at(64), None, "a collapsed sweep has no knee");
        let path = std::env::temp_dir().join("BENCH_loadgen_connstest.json");
        let path = path.to_str().unwrap().to_string();
        write_connections_json(&path, &cfg, &[2000.0, 4000.0], &planes).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"loadgen_connections\""));
        assert!(text.contains("\"plane\": \"threads\""));
        assert!(text.contains("\"plane\": \"epoll\""));
        assert!(text.contains("\"connections\": 64"));
        assert!(text.contains("\"knee_qps\": 4000.0"));
        assert!(text.contains("\"knee_qps\": null"));
        assert!(text.contains("\"qps_points\": [2000.0, 4000.0]"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn seal_overhead_measures_and_serializes() {
        let s = measure_seal_overhead(512);
        assert_eq!(s.frames, 512);
        assert!(s.plain_ns_per_frame > 0.0);
        assert!(
            s.sealed_ns_per_frame >= s.plain_ns_per_frame * 0.5,
            "sealing cannot plausibly be 2x faster than not sealing: \
             plain {:.1}ns sealed {:.1}ns",
            s.plain_ns_per_frame,
            s.sealed_ns_per_frame
        );
        let sweep = SweepReport { points: Vec::new(), knee_qps: None };
        let path = std::env::temp_dir().join("BENCH_loadgen_sealtest.json");
        let path = path.to_str().unwrap().to_string();
        write_json(&path, &LoadgenConfig::default(), &sweep, Some(&s), None, None).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"seal_overhead\": {\"frames\": 512"));
        assert!(text.contains("\"overhead_pct\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn telemetry_overhead_measures_and_serializes() {
        let t = measure_telemetry_overhead(512);
        assert_eq!(t.requests, 512);
        assert!(t.baseline_ns_per_req > 0.0);
        assert!(t.disabled_ns_per_req > 0.0);
        assert!(t.sampled_ns_per_req > 0.0);
        // A hard upper bound, not a noise assertion (CI machines are
        // noisy): the disabled single-branch path cannot plausibly
        // double the per-request frame cost.
        assert!(
            t.disabled_ns_per_req < t.baseline_ns_per_req * 2.0,
            "disabled tracer path too expensive: baseline {:.1}ns disabled {:.1}ns",
            t.baseline_ns_per_req,
            t.disabled_ns_per_req
        );
        let sweep = SweepReport { points: Vec::new(), knee_qps: None };
        let path = std::env::temp_dir().join("BENCH_loadgen_telemetrytest.json");
        let path = path.to_str().unwrap().to_string();
        write_json(&path, &LoadgenConfig::default(), &sweep, None, Some(&t), None).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"telemetry_overhead\": {\"requests\": 512"));
        assert!(text.contains("\"disabled_overhead_pct\""));
        assert!(text.contains("\"sampled_overhead_pct\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn journal_overhead_measures_and_serializes() {
        let j = measure_journal_overhead(512).unwrap();
        assert_eq!(j.events, 512);
        assert!(j.off_ns_per_event > 0.0);
        assert!(j.buffered_ns_per_event > 0.0);
        assert!(j.fsync_ns_per_event > 0.0);
        // Physics, not a tight noise bound: persisting to a file
        // cannot plausibly be 2x faster than not persisting at all.
        assert!(
            j.buffered_ns_per_event >= j.off_ns_per_event * 0.5,
            "buffered WAL cheaper than no WAL: off {:.1}ns buffered {:.1}ns",
            j.off_ns_per_event,
            j.buffered_ns_per_event
        );
        let sweep = SweepReport { points: Vec::new(), knee_qps: None };
        let path = std::env::temp_dir().join("BENCH_loadgen_journaltest.json");
        let path = path.to_str().unwrap().to_string();
        write_json(&path, &LoadgenConfig::default(), &sweep, None, None, Some(&j)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"journal_persistence_overhead\": {\"events\": 512"));
        assert!(text.contains("\"buffered_overhead_pct\""));
        assert!(text.contains("\"fsync_overhead_pct\""));
        let _ = std::fs::remove_file(&path);
    }
}
