//! Mutual authentication and per-frame integrity for the fabric.
//!
//! The fabric's reliability story (golden-value verification, zero-loss
//! failover) is only as strong as the integrity of the frames carrying
//! it: on a hostile network a rogue `Register` can claim a ring slot and
//! black-hole a `FunctionKind`, and an on-path peer can replay a
//! `Welcome` or flip a bit in a `Result` undetected. This module closes
//! that gap with **no external dependencies** — the offline vendor set
//! has no TLS or crypto crate, so the primitives are hand-rolled from
//! their specs:
//!
//! * SHA-256 (FIPS 180-4) + HMAC-SHA256 (RFC 2104) + a single-block
//!   HKDF (RFC 5869) for key derivation,
//! * ChaCha20 (RFC 8439) as the stream cipher,
//! * a 3-message noise-style pre-shared-key handshake with fresh
//!   per-connection nonces and constant-time MAC comparison,
//! * an encrypt-then-MAC seal with **implicit monotonic per-direction
//!   frame counters**: the counter is never transmitted, both sides
//!   count frames independently (TCP preserves ordering), so a replayed,
//!   reordered, or dropped-and-reinserted frame fails its MAC.
//!
//! Handshake (client = connecting side, server = accepting side):
//!
//! ```text
//! C -> S  [HS_MAGIC, CLIENT_HELLO,  cn (32 bytes)]
//! S -> C  [HS_MAGIC, SERVER_HELLO,  sn (32) , HMAC(k_auth, "srv" || cn || sn)]
//! C -> S  [HS_MAGIC, CLIENT_CONFIRM,          HMAC(k_auth, "cli" || cn || sn)]
//! ```
//!
//! where `k_auth = HMAC(psk, hs-label)`. Both MACs cover both nonces, so
//! a replayed transcript (either direction) fails against the fresh
//! nonce the honest side just generated. Session keys come from
//! `HKDF-Extract(salt = cn || sn, ikm = psk)` followed by four
//! single-block expands (c2s/s2c x cipher/mac), giving each direction an
//! independent cipher and MAC key.
//!
//! Sealed frames ride inside the existing length-prefixed transport:
//!
//! ```text
//! [len u32 LE][0xE4 marker][ChaCha20 ciphertext][16-byte truncated HMAC tag]
//! ```
//!
//! The MAC covers `[direction byte] || counter (LE u64) || ciphertext`;
//! the ChaCha20 nonce is `[dir, 0, 0, 0, counter LE u64]`, so a
//! (key, nonce) pair is never reused. Marker bytes 0xE4/0xE5 are
//! disjoint from every plaintext wire version (1..=4), so a plaintext
//! endpoint can reject sealed traffic with a helpful error and vice
//! versa — there is no byte sequence that parses both ways.
//!
//! All reads here are **deadline-bounded** (see [`read_frame_bounded`]):
//! once the first byte of a frame arrives, the rest must follow within
//! [`FRAME_DEADLINE`], which is what defeats slowloris-style tricklers
//! on both fabric ports.

use crate::fabric::wire::{Msg, FRAME_HEADER_LEN, MAX_FRAME};
use anyhow::{bail, Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

/// First payload byte of a sealed frame. Deliberately outside the
/// plaintext wire-version range so the two framings cannot be confused.
pub const SEALED_MARKER: u8 = 0xE4;
/// First payload byte of every handshake message.
pub const HS_MAGIC: u8 = 0xE5;

const HS_CLIENT_HELLO: u8 = 1;
const HS_SERVER_HELLO: u8 = 2;
const HS_CLIENT_CONFIRM: u8 = 3;

/// Truncated HMAC-SHA256 tag appended to every sealed frame.
pub const TAG_LEN: usize = 16;
/// Per-connection ephemeral nonce length (client and server).
pub const NONCE_LEN: usize = 32;
/// Full handshake MAC length.
pub const MAC_LEN: usize = 32;
/// Bytes a seal adds to a payload: marker + truncated tag.
pub const SEAL_OVERHEAD: usize = 1 + TAG_LEN;

/// A whole handshake message must arrive within this budget, and each
/// handshake write gets the same bound.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(2);
/// Once the first byte of a frame has arrived, the remainder must land
/// within this deadline — a 1 byte/sec trickler is cut off here instead
/// of wedging a reader thread (or the registration accept loop) forever.
pub const FRAME_DEADLINE: Duration = Duration::from_secs(2);

/// Direction bytes: they salt both the MAC input and the cipher nonce so
/// the two half-duplex streams can never be cross-spliced.
const DIR_C2S: u8 = 0xC1;
const DIR_S2C: u8 = 0x51;

const HS_AUTH_LABEL: &[u8] = b"remus-fabric-hs-auth-v1";
const HS_SRV_LABEL: &[u8] = b"remus-fabric-hs-srv-v1";
const HS_CLI_LABEL: &[u8] = b"remus-fabric-hs-cli-v1";
const PSK_LABEL: &[u8] = b"remus-fabric-psk-v1";

// ---------------------------------------------------------------------------
// SHA-256 (FIPS 180-4)
// ---------------------------------------------------------------------------

const SHA_K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Streaming SHA-256.
struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

impl Sha256 {
    fn new() -> Self {
        Self {
            state: [
                0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
                0x1f83d9ab, 0x5be0cd19,
            ],
            buf: [0u8; 64],
            buf_len: 0,
            total_len: 0,
        }
    }

    fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Length padding bypasses `update` so total_len stays untouched.
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(SHA_K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

/// SHA-256 over the concatenation of `parts`.
pub fn sha256(parts: &[&[u8]]) -> [u8; 32] {
    let mut h = Sha256::new();
    for p in parts {
        h.update(p);
    }
    h.finalize()
}

/// HMAC-SHA256 over the concatenation of `parts` (RFC 2104).
pub fn hmac_sha256(key: &[u8], parts: &[&[u8]]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(&[key]));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0x36u8; 64];
    let mut opad = [0x5cu8; 64];
    for i in 0..64 {
        ipad[i] ^= k[i];
        opad[i] ^= k[i];
    }
    let mut inner = Sha256::new();
    inner.update(&ipad);
    for p in parts {
        inner.update(p);
    }
    let inner_hash = inner.finalize();
    let mut outer = Sha256::new();
    outer.update(&opad);
    outer.update(&inner_hash);
    outer.finalize()
}

// ---------------------------------------------------------------------------
// ChaCha20 (RFC 8439)
// ---------------------------------------------------------------------------

#[inline]
fn quarter_round(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(16);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(12);
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(8);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(7);
}

fn chacha20_block(key: &[u8; 32], nonce: &[u8; 12], counter: u32) -> [u8; 64] {
    let mut state = [0u32; 16];
    state[0] = 0x61707865;
    state[1] = 0x3320646e;
    state[2] = 0x79622d32;
    state[3] = 0x6b206574;
    for i in 0..8 {
        state[4 + i] = u32::from_le_bytes(key[i * 4..i * 4 + 4].try_into().unwrap());
    }
    state[12] = counter;
    for i in 0..3 {
        state[13 + i] = u32::from_le_bytes(nonce[i * 4..i * 4 + 4].try_into().unwrap());
    }
    let mut work = state;
    for _ in 0..10 {
        quarter_round(&mut work, 0, 4, 8, 12);
        quarter_round(&mut work, 1, 5, 9, 13);
        quarter_round(&mut work, 2, 6, 10, 14);
        quarter_round(&mut work, 3, 7, 11, 15);
        quarter_round(&mut work, 0, 5, 10, 15);
        quarter_round(&mut work, 1, 6, 11, 12);
        quarter_round(&mut work, 2, 7, 8, 13);
        quarter_round(&mut work, 3, 4, 9, 14);
    }
    let mut out = [0u8; 64];
    for i in 0..16 {
        let word = work[i].wrapping_add(state[i]);
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_le_bytes());
    }
    out
}

/// XOR `data` in place with the ChaCha20 keystream starting at block
/// `counter_start`.
pub fn chacha20_xor(key: &[u8; 32], nonce: &[u8; 12], counter_start: u32, data: &mut [u8]) {
    let mut counter = counter_start;
    for chunk in data.chunks_mut(64) {
        let block = chacha20_block(key, nonce, counter);
        for (b, k) in chunk.iter_mut().zip(block.iter()) {
            *b ^= k;
        }
        counter = counter.wrapping_add(1);
    }
}

/// Constant-time byte comparison: the XOR-accumulate loop runs to the
/// end regardless of where the first mismatch is.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc = 0u8;
    for (x, y) in a.iter().zip(b.iter()) {
        acc |= x ^ y;
    }
    acc == 0
}

// ---------------------------------------------------------------------------
// Pre-shared key
// ---------------------------------------------------------------------------

/// The fleet-wide pre-shared key, normalised to 32 bytes by hashing the
/// raw key-file material under a fixed label. Cloned freely (it is just
/// 32 bytes); `Debug` never prints key bytes.
#[derive(Clone)]
pub struct Psk {
    key: [u8; 32],
}

impl std::fmt::Debug for Psk {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Psk(<redacted>)")
    }
}

impl Psk {
    /// Derive the key from raw material (the bytes of a `--psk-file`).
    /// Leading/trailing ASCII whitespace is trimmed so `echo secret >
    /// psk` and `printf secret > psk` produce the same key.
    pub fn from_material(material: &[u8]) -> Result<Self> {
        let start = material.iter().position(|b| !b.is_ascii_whitespace());
        let trimmed = match start {
            Some(s) => {
                let end = material.iter().rposition(|b| !b.is_ascii_whitespace()).unwrap();
                &material[s..=end]
            }
            None => &[][..],
        };
        if trimmed.is_empty() {
            bail!("PSK material is empty (the key file must contain a non-whitespace secret)");
        }
        Ok(Self { key: sha256(&[PSK_LABEL, trimmed]) })
    }

    /// Load the key from a file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let material = std::fs::read(path)
            .with_context(|| format!("read PSK file {}", path.display()))?;
        Self::from_material(&material)
            .with_context(|| format!("derive PSK from {}", path.display()))
    }
}

/// A fresh 32-byte per-connection nonce. Prefers `/dev/urandom`; falls
/// back to SplitMix64 over (time, pid, global counter) — the handshake
/// only needs uniqueness per connection, not secrecy, for replayed
/// transcripts to fail.
fn fresh_nonce() -> [u8; 32] {
    let mut nonce = [0u8; 32];
    if let Ok(mut f) = std::fs::File::open("/dev/urandom") {
        if f.read_exact(&mut nonce).is_ok() {
            return nonce;
        }
    }
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let seed = t ^ (std::process::id() as u64).rotate_left(32) ^ COUNTER.fetch_add(1, Ordering::Relaxed);
    let mut sm = crate::util::rng::SplitMix64::new(seed);
    for chunk in nonce.chunks_mut(8) {
        chunk.copy_from_slice(&sm.next_u64().to_le_bytes());
    }
    nonce
}

// ---------------------------------------------------------------------------
// AEAD seal (encrypt-then-MAC with implicit frame counters)
// ---------------------------------------------------------------------------

/// One direction of a sealed connection. `seal`/`open` advance an
/// implicit monotonic frame counter: both sides count independently, so
/// a replayed or reordered frame computes its MAC over the wrong
/// counter and is rejected.
#[derive(Clone)]
pub struct Seal {
    cipher_key: [u8; 32],
    mac_key: [u8; 32],
    dir: u8,
    counter: u64,
}

impl std::fmt::Debug for Seal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Seal(dir={:#04x}, counter={})", self.dir, self.counter)
    }
}

impl Seal {
    fn new(cipher_key: [u8; 32], mac_key: [u8; 32], dir: u8) -> Self {
        Self { cipher_key, mac_key, dir, counter: 0 }
    }

    fn nonce(&self) -> [u8; 12] {
        let mut n = [0u8; 12];
        n[0] = self.dir;
        n[4..12].copy_from_slice(&self.counter.to_le_bytes());
        n
    }

    /// Seal a plaintext payload: `[marker][ciphertext][tag16]`.
    pub fn seal(&mut self, plain: &[u8]) -> Vec<u8> {
        let mut ct = plain.to_vec();
        chacha20_xor(&self.cipher_key, &self.nonce(), 1, &mut ct);
        let tag = hmac_sha256(
            &self.mac_key,
            &[&[self.dir], &self.counter.to_le_bytes(), &ct],
        );
        let mut out = Vec::with_capacity(SEAL_OVERHEAD + ct.len());
        out.push(SEALED_MARKER);
        out.extend_from_slice(&ct);
        out.extend_from_slice(&tag[..TAG_LEN]);
        self.counter += 1;
        out
    }

    /// Verify and decrypt a sealed payload. The counter only advances on
    /// success, so one garbage frame does not desync an honest peer that
    /// never gets to send again anyway (the connection is dropped).
    pub fn open(&mut self, sealed: &[u8]) -> Result<Vec<u8>> {
        // Smallest sealed frame: marker + 2-byte header ciphertext + tag.
        if sealed.len() < SEAL_OVERHEAD + 2 {
            bail!("sealed frame too short ({} bytes)", sealed.len());
        }
        if sealed[0] != SEALED_MARKER {
            bail!(
                "expected a sealed frame, got leading byte {:#04x} (plaintext peer on an authenticated port?)",
                sealed[0]
            );
        }
        let ct = &sealed[1..sealed.len() - TAG_LEN];
        let tag = &sealed[sealed.len() - TAG_LEN..];
        let want = hmac_sha256(
            &self.mac_key,
            &[&[self.dir], &self.counter.to_le_bytes(), ct],
        );
        if !ct_eq(tag, &want[..TAG_LEN]) {
            bail!("frame failed integrity check (tampered, replayed, or out of order)");
        }
        let mut plain = ct.to_vec();
        chacha20_xor(&self.cipher_key, &self.nonce(), 1, &mut plain);
        self.counter += 1;
        Ok(plain)
    }
}

/// Both directions of a freshly keyed connection, from this endpoint's
/// point of view: `tx` seals what we send, `rx` opens what we receive.
pub struct Channel {
    pub tx: Seal,
    pub rx: Seal,
}

/// Directional session keys in canonical (client-to-server /
/// server-to-client) orientation, before an endpoint picks sides.
pub struct SessionKeys {
    pub c2s: Seal,
    pub s2c: Seal,
}

/// HKDF-style session-key derivation: extract with the two handshake
/// nonces as salt, then four single-block expands.
pub fn derive_keys(psk: &Psk, client_nonce: &[u8; 32], server_nonce: &[u8; 32]) -> SessionKeys {
    let mut salt = [0u8; 64];
    salt[..32].copy_from_slice(client_nonce);
    salt[32..].copy_from_slice(server_nonce);
    let prk = hmac_sha256(&salt, &[&psk.key]);
    let expand = |info: &[u8]| hmac_sha256(&prk, &[info, &[1u8]]);
    SessionKeys {
        c2s: Seal::new(
            expand(b"remus c2s cipher v1"),
            expand(b"remus c2s mac v1"),
            DIR_C2S,
        ),
        s2c: Seal::new(
            expand(b"remus s2c cipher v1"),
            expand(b"remus s2c mac v1"),
            DIR_S2C,
        ),
    }
}

// ---------------------------------------------------------------------------
// Deadline-bounded frame transport
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame.
fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME + SEAL_OVERHEAD {
        bail!("frame too large: {} bytes", payload.len());
    }
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Read exactly `buf.len()` bytes. `idle` bounds the wait for the
/// *first* byte (`None` = block indefinitely between frames); once any
/// byte has arrived, `deadline` is armed and every subsequent wait is
/// clamped to the time remaining. Returns `Ok(false)` on a clean EOF
/// before the first byte (only when `allow_eof`).
fn read_exact_bounded(
    stream: &mut TcpStream,
    buf: &mut [u8],
    idle: Option<Duration>,
    deadline: &mut Option<Instant>,
    allow_eof: bool,
) -> Result<bool> {
    let mut got = 0usize;
    while got < buf.len() {
        let timeout = match *deadline {
            Some(d) => {
                let remaining = d.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    bail!("frame incomplete after {:?} (slow or stalled peer)", FRAME_DEADLINE);
                }
                // set_read_timeout rejects a zero Duration; clamp up.
                Some(remaining.max(Duration::from_millis(1)))
            }
            None => idle,
        };
        stream.set_read_timeout(timeout).context("set read timeout")?;
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if allow_eof && got == 0 && deadline.is_none() {
                    return Ok(false);
                }
                bail!("connection closed mid-frame ({got} of {} bytes)", buf.len());
            }
            Ok(n) => {
                if deadline.is_none() {
                    *deadline = Some(Instant::now() + FRAME_DEADLINE);
                }
                got += n;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                bail!("read timed out ({got} of {} bytes)", buf.len());
            }
            Err(e) => return Err(e).context("frame read"),
        }
    }
    Ok(true)
}

/// Read one length-prefixed frame payload with slowloris protection:
/// `idle` bounds the wait between frames, [`FRAME_DEADLINE`] bounds the
/// time from a frame's first byte to its last. `Ok(None)` is a clean
/// EOF at a frame boundary.
pub fn read_frame_bounded(
    stream: &mut TcpStream,
    idle: Option<Duration>,
) -> Result<Option<Vec<u8>>> {
    let mut deadline = None;
    let mut len_buf = [0u8; 4];
    if !read_exact_bounded(stream, &mut len_buf, idle, &mut deadline, true)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len < 2 || len > MAX_FRAME + SEAL_OVERHEAD {
        bail!("implausible frame length {len}");
    }
    let mut payload = vec![0u8; len];
    read_exact_bounded(stream, &mut payload, idle, &mut deadline, false)?;
    Ok(Some(payload))
}

// ---------------------------------------------------------------------------
// Handshake
// ---------------------------------------------------------------------------

/// Run the connecting side of the PSK handshake. On success the peer
/// has proven knowledge of the PSK and fresh session keys are derived.
/// Sets a [`HANDSHAKE_TIMEOUT`] write timeout on the stream; callers
/// that want a different steady-state write timeout must reset it.
pub fn client_handshake(stream: &mut TcpStream, psk: &Psk) -> Result<Channel> {
    stream.set_write_timeout(Some(HANDSHAKE_TIMEOUT)).context("set write timeout")?;
    let cn = fresh_nonce();
    let mut hello = Vec::with_capacity(2 + NONCE_LEN);
    hello.push(HS_MAGIC);
    hello.push(HS_CLIENT_HELLO);
    hello.extend_from_slice(&cn);
    write_frame(stream, &hello).context("send ClientHello")?;

    let reply = read_frame_bounded(stream, Some(HANDSHAKE_TIMEOUT))
        .context("read ServerHello")?
        .context("peer closed during handshake")?;
    if reply.len() != 2 + NONCE_LEN + MAC_LEN
        || reply[0] != HS_MAGIC
        || reply[1] != HS_SERVER_HELLO
    {
        bail!("unexpected handshake reply (is the peer running with the same --psk-file?)");
    }
    let sn: [u8; 32] = reply[2..2 + NONCE_LEN].try_into().unwrap();
    let srv_mac = &reply[2 + NONCE_LEN..];
    let k_auth = hmac_sha256(&psk.key, &[HS_AUTH_LABEL]);
    let want = hmac_sha256(&k_auth, &[HS_SRV_LABEL, &cn, &sn]);
    if !ct_eq(srv_mac, &want) {
        bail!("server failed PSK authentication (wrong key or replayed transcript)");
    }

    let cli_mac = hmac_sha256(&k_auth, &[HS_CLI_LABEL, &cn, &sn]);
    let mut confirm = Vec::with_capacity(2 + MAC_LEN);
    confirm.push(HS_MAGIC);
    confirm.push(HS_CLIENT_CONFIRM);
    confirm.extend_from_slice(&cli_mac);
    write_frame(stream, &confirm).context("send ClientConfirm")?;

    let keys = derive_keys(psk, &cn, &sn);
    Ok(Channel { tx: keys.c2s, rx: keys.s2c })
}

/// Run the accepting side of the PSK handshake. A plaintext or
/// wrong-key peer fails here within [`HANDSHAKE_TIMEOUT`] without ever
/// reaching the wire codec.
pub fn server_handshake(stream: &mut TcpStream, psk: &Psk) -> Result<Channel> {
    stream.set_write_timeout(Some(HANDSHAKE_TIMEOUT)).context("set write timeout")?;
    let hello = read_frame_bounded(stream, Some(HANDSHAKE_TIMEOUT))
        .context("read ClientHello")?
        .context("peer closed before handshake")?;
    if hello.len() != 2 + NONCE_LEN || hello[0] != HS_MAGIC || hello[1] != HS_CLIENT_HELLO {
        bail!(
            "peer did not start a PSK handshake (leading byte {:#04x}; plaintext peer on an authenticated port?)",
            hello[0]
        );
    }
    let cn: [u8; 32] = hello[2..].try_into().unwrap();
    let sn = fresh_nonce();
    let k_auth = hmac_sha256(&psk.key, &[HS_AUTH_LABEL]);
    let srv_mac = hmac_sha256(&k_auth, &[HS_SRV_LABEL, &cn, &sn]);
    let mut reply = Vec::with_capacity(2 + NONCE_LEN + MAC_LEN);
    reply.push(HS_MAGIC);
    reply.push(HS_SERVER_HELLO);
    reply.extend_from_slice(&sn);
    reply.extend_from_slice(&srv_mac);
    write_frame(stream, &reply).context("send ServerHello")?;

    let confirm = read_frame_bounded(stream, Some(HANDSHAKE_TIMEOUT))
        .context("read ClientConfirm")?
        .context("peer closed mid-handshake")?;
    if confirm.len() != 2 + MAC_LEN || confirm[0] != HS_MAGIC || confirm[1] != HS_CLIENT_CONFIRM {
        bail!("malformed ClientConfirm");
    }
    let want = hmac_sha256(&k_auth, &[HS_CLI_LABEL, &cn, &sn]);
    if !ct_eq(&confirm[2..], &want) {
        bail!("client failed PSK authentication (wrong key or replayed transcript)");
    }

    let keys = derive_keys(psk, &cn, &sn);
    Ok(Channel { tx: keys.s2c, rx: keys.c2s })
}

// ---------------------------------------------------------------------------
// Framed message streams (sealed or plaintext)
// ---------------------------------------------------------------------------

/// Reads wire messages off a stream, opening the seal when one is
/// configured, with deadline-bounded reads either way.
pub struct FrameReader {
    stream: TcpStream,
    seal: Option<Seal>,
    idle: Option<Duration>,
}

impl FrameReader {
    /// `idle` bounds the wait *between* frames (`None` = block); the
    /// per-frame [`FRAME_DEADLINE`] always applies.
    pub fn new(stream: TcpStream, seal: Option<Seal>, idle: Option<Duration>) -> Self {
        Self { stream, seal, idle }
    }

    pub fn is_sealed(&self) -> bool {
        self.seal.is_some()
    }

    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    /// Read the next message. `Ok(None)` is a clean EOF at a frame
    /// boundary; every tamper/replay/timeout path is an `Err`.
    pub fn recv(&mut self) -> Result<Option<Msg>> {
        let payload = match read_frame_bounded(&mut self.stream, self.idle)? {
            Some(p) => p,
            None => return Ok(None),
        };
        let plain = decode_payload(&mut self.seal, payload)?;
        Ok(Some(Msg::from_bytes(&plain)?))
    }

    /// Take the reader apart for a nonblocking transport: the raw stream
    /// plus the receive seal, preserving the seal's frame counter so an
    /// established session can move onto a reactor mid-stream.
    pub fn into_parts(self) -> (TcpStream, Option<Seal>) {
        (self.stream, self.seal)
    }
}

/// Unseal (or plaintext-validate) one frame payload — the single
/// decode path shared by the blocking [`FrameReader`] and the
/// incremental [`FrameDecoder`], so both transports reject sealed,
/// handshake, and tampered frames with identical semantics.
fn decode_payload(seal: &mut Option<Seal>, payload: Vec<u8>) -> Result<Vec<u8>> {
    match seal {
        Some(seal) => seal.open(&payload),
        None => match payload[0] {
            SEALED_MARKER => bail!(
                "received a sealed frame on a plaintext endpoint (peer uses --psk-file, we do not)"
            ),
            HS_MAGIC => bail!(
                "received a PSK handshake on a plaintext endpoint (peer uses --psk-file, we do not)"
            ),
            _ => Ok(payload),
        },
    }
}

/// Incremental frame decoder for nonblocking sockets: bytes go in as
/// they arrive ([`FrameDecoder::push`]), complete messages come out
/// ([`FrameDecoder::try_next`]). Length validation, seal opening (with
/// the same implicit counter discipline), and plaintext marker
/// rejection are byte-for-byte identical to [`FrameReader::recv`] —
/// only the blocking strategy differs. The caller owns the slowloris
/// deadline: [`FrameDecoder::mid_frame`] says when a partial frame is
/// buffered and [`FRAME_DEADLINE`] should be armed.
pub struct FrameDecoder {
    seal: Option<Seal>,
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new(seal: Option<Seal>) -> Self {
        Self { seal, buf: Vec::new() }
    }

    pub fn is_sealed(&self) -> bool {
        self.seal.is_some()
    }

    /// Feed bytes read off the socket.
    pub fn push(&mut self, data: &[u8]) {
        self.buf.extend_from_slice(data);
    }

    /// True while a partially received frame sits in the buffer — the
    /// transport should be holding a [`FRAME_DEADLINE`] against the peer.
    pub fn mid_frame(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Bytes currently buffered but not yet decoded.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Decode the next complete message, or `Ok(None)` if more bytes are
    /// needed. Errors are terminal for the connection, exactly as a
    /// [`FrameReader::recv`] error would be.
    pub fn try_next(&mut self) -> Result<Option<Msg>> {
        if self.buf.len() < FRAME_HEADER_LEN {
            return Ok(None);
        }
        let len =
            u32::from_le_bytes(self.buf[..FRAME_HEADER_LEN].try_into().unwrap()) as usize;
        if len < 2 || len > MAX_FRAME + SEAL_OVERHEAD {
            bail!("implausible frame length {len}");
        }
        if self.buf.len() < FRAME_HEADER_LEN + len {
            return Ok(None);
        }
        let payload: Vec<u8> = self.buf[FRAME_HEADER_LEN..FRAME_HEADER_LEN + len].to_vec();
        self.buf.drain(..FRAME_HEADER_LEN + len);
        let plain = decode_payload(&mut self.seal, payload)?;
        Ok(Some(Msg::from_bytes(&plain)?))
    }
}

/// Encode one message into its full wire bytes (`[len u32 LE][payload]`),
/// sealing when a seal is configured. Sealing happens at encode time so
/// the implicit frame counters advance in *enqueue* order even when the
/// actual socket writes are coalesced and batched later — the bytes a
/// reactor queues are exactly the bytes [`FrameWriter::send`] would have
/// written.
pub fn encode_frame(msg: &Msg, seal: &mut Option<Seal>) -> Result<Vec<u8>> {
    let payload = msg.to_bytes();
    let payload = match seal {
        Some(s) => s.seal(&payload),
        None => payload,
    };
    if payload.len() > MAX_FRAME + SEAL_OVERHEAD {
        bail!("frame too large: {} bytes", payload.len());
    }
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Writes wire messages onto a stream, sealing when configured.
pub struct FrameWriter {
    stream: TcpStream,
    seal: Option<Seal>,
}

impl FrameWriter {
    pub fn new(stream: TcpStream, seal: Option<Seal>) -> Self {
        Self { stream, seal }
    }

    pub fn is_sealed(&self) -> bool {
        self.seal.is_some()
    }

    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    pub fn send(&mut self, msg: &Msg) -> Result<()> {
        let payload = msg.to_bytes();
        match &mut self.seal {
            Some(seal) => {
                let sealed = seal.seal(&payload);
                write_frame(&mut self.stream, &sealed)
            }
            None => write_frame(&mut self.stream, &payload),
        }
    }

    /// Take the writer apart for a nonblocking transport: the raw
    /// stream plus the transmit seal, preserving the seal's frame
    /// counter so an established session can move onto a reactor
    /// mid-stream (the counterpart of [`FrameReader::into_parts`]).
    pub fn into_parts(self) -> (TcpStream, Option<Seal>) {
        (self.stream, self.seal)
    }
}

fn split(
    mut stream: TcpStream,
    psk: Option<&Psk>,
    idle: Option<Duration>,
    is_client: bool,
) -> Result<(FrameReader, FrameWriter)> {
    let channel = match psk {
        Some(p) => Some(if is_client {
            client_handshake(&mut stream, p)?
        } else {
            server_handshake(&mut stream, p)?
        }),
        None => None,
    };
    let write_half = stream.try_clone().context("clone stream for writer")?;
    let (tx, rx) = match channel {
        Some(c) => (Some(c.tx), Some(c.rx)),
        None => (None, None),
    };
    Ok((FrameReader::new(stream, rx, idle), FrameWriter::new(write_half, tx)))
}

/// Handshake (when a PSK is configured) as the connecting side, then
/// split the stream into a reader and a writer sharing the session.
pub fn client_split(
    stream: TcpStream,
    psk: Option<&Psk>,
    idle: Option<Duration>,
) -> Result<(FrameReader, FrameWriter)> {
    split(stream, psk, idle, true)
}

/// Handshake (when a PSK is configured) as the accepting side, then
/// split the stream into a reader and a writer sharing the session.
pub fn server_split(
    stream: TcpStream,
    psk: Option<&Psk>,
    idle: Option<Duration>,
) -> Result<(FrameReader, FrameWriter)> {
    split(stream, psk, idle, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn sha256_known_vectors() {
        assert_eq!(
            hex(&sha256(&[b"abc"])),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(&[b""])),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(&[b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"])),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // Streaming across arbitrary chunk boundaries matches one-shot.
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let oneshot = sha256(&[&data]);
        let mut h = Sha256::new();
        for chunk in data.chunks(7) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), oneshot);
    }

    #[test]
    fn hmac_sha256_rfc4231_case2() {
        let mac = hmac_sha256(b"Jefe", &[b"what do ya want for nothing?"]);
        assert_eq!(
            hex(&mac),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // Split parts hash identically to the concatenation.
        let split = hmac_sha256(b"Jefe", &[b"what do ya want", b" for nothing?"]);
        assert_eq!(mac, split);
    }

    #[test]
    fn chacha20_rfc8439_keystream() {
        let key: [u8; 32] = std::array::from_fn(|i| i as u8);
        let nonce: [u8; 12] = [0, 0, 0, 9, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let mut data = [0u8; 64];
        chacha20_xor(&key, &nonce, 1, &mut data);
        assert_eq!(
            hex(&data),
            "10f1e7e4d13b5915500fdd1fa32071c4c7d1f4c733c068030422aa9ac3d46c4e\
             d2826446079faa0914c2d705d98b02a2b5129cd1de164eb9cbd083e8a2503c4e"
        );
    }

    #[test]
    fn ct_eq_basics() {
        assert!(ct_eq(b"same", b"same"));
        assert!(!ct_eq(b"same", b"sama"));
        assert!(!ct_eq(b"short", b"longer"));
        assert!(ct_eq(b"", b""));
    }

    #[test]
    fn psk_is_stable_and_trimmed() {
        let a = Psk::from_material(b"secret\n").unwrap();
        let b = Psk::from_material(b"  secret  ").unwrap();
        let c = Psk::from_material(b"other").unwrap();
        assert_eq!(a.key, b.key);
        assert_ne!(a.key, c.key);
        assert!(Psk::from_material(b"  \n\t ").is_err());
        assert_eq!(format!("{a:?}"), "Psk(<redacted>)");
    }

    #[test]
    fn seal_roundtrip_and_counter_advance() {
        let psk = Psk::from_material(b"k").unwrap();
        let keys_a = derive_keys(&psk, &[1u8; 32], &[2u8; 32]);
        let keys_b = derive_keys(&psk, &[1u8; 32], &[2u8; 32]);
        let mut tx = keys_a.c2s;
        let mut rx = keys_b.c2s;
        for i in 0..10u64 {
            let msg = format!("frame {i}");
            let sealed = tx.seal(msg.as_bytes());
            assert_eq!(sealed[0], SEALED_MARKER);
            assert_eq!(rx.open(&sealed).unwrap(), msg.as_bytes());
        }
        // Distinct nonces mean two frames with identical plaintext get
        // different ciphertexts.
        let mut tx2 = derive_keys(&psk, &[1u8; 32], &[2u8; 32]).c2s;
        let s1 = tx2.seal(b"same payload 00");
        let s2 = tx2.seal(b"same payload 00");
        assert_ne!(s1, s2);
    }

    #[test]
    fn seal_rejects_tamper_replay_truncation_and_cross_direction() {
        let psk = Psk::from_material(b"k").unwrap();
        let keys = derive_keys(&psk, &[3u8; 32], &[4u8; 32]);
        let mut tx = keys.c2s;
        let mut rx = derive_keys(&psk, &[3u8; 32], &[4u8; 32]).c2s;
        let sealed = tx.seal(b"payload-0");
        // Single-bit flips anywhere (marker, ct, tag) must be rejected.
        for byte in 0..sealed.len() {
            let mut bad = sealed.clone();
            bad[byte] ^= 1;
            assert!(rx.open(&bad).is_err(), "flip at byte {byte} must fail");
        }
        // The pristine frame still opens (counter untouched by failures).
        assert_eq!(rx.open(&sealed).unwrap(), b"payload-0");
        // Replay: counter has advanced, same bytes must now fail.
        assert!(rx.open(&sealed).is_err(), "replayed frame must fail");
        // Truncations.
        for cut in 0..sealed.len() {
            assert!(rx.open(&sealed[..cut]).is_err());
        }
        // Cross-direction splice: a c2s frame must not open as s2c.
        let mut tx3 = derive_keys(&psk, &[3u8; 32], &[4u8; 32]).c2s;
        let mut rx_s2c = derive_keys(&psk, &[3u8; 32], &[4u8; 32]).s2c;
        assert!(rx_s2c.open(&tx3.seal(b"payload-0")).is_err());
    }

    #[test]
    fn loopback_handshake_seals_both_directions() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let psk = Psk::from_material(b"fleet-secret").unwrap();
        let psk_srv = psk.clone();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let (mut r, mut w) = server_split(stream, Some(&psk_srv), Some(HANDSHAKE_TIMEOUT)).unwrap();
            let got = r.recv().unwrap().expect("one message");
            assert_eq!(got, Msg::HealthReq);
            w.send(&Msg::Shutdown).unwrap();
            assert!(r.recv().unwrap().is_none(), "clean EOF");
        });
        let stream = TcpStream::connect(addr).unwrap();
        let (mut r, mut w) = client_split(stream, Some(&psk), Some(HANDSHAKE_TIMEOUT)).unwrap();
        assert!(r.is_sealed() && w.is_sealed());
        w.send(&Msg::HealthReq).unwrap();
        assert_eq!(r.recv().unwrap().expect("one message"), Msg::Shutdown);
        drop(w);
        drop(r);
        server.join().unwrap();
    }

    #[test]
    fn wrong_psk_fails_both_ends() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let psk = Psk::from_material(b"right").unwrap();
            assert!(server_handshake(&mut stream, &psk).is_err());
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let psk = Psk::from_material(b"wrong").unwrap();
        assert!(client_handshake(&mut stream, &psk).is_err());
        server.join().unwrap();
    }

    #[test]
    fn plaintext_peer_is_rejected_by_sealed_endpoint_and_vice_versa() {
        // Sealed server, plaintext client: the server handshake must
        // reject the plaintext frame (which starts with a version byte).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let psk = Psk::from_material(b"k").unwrap();
            assert!(server_handshake(&mut stream, &psk).is_err());
        });
        let stream = TcpStream::connect(addr).unwrap();
        let (_r, mut w) = client_split(stream, None, None).unwrap();
        let _ = w.send(&Msg::HealthReq);
        server.join().unwrap();

        // Plaintext reader, sealed-looking bytes: helpful rejection.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut r = FrameReader::new(stream, None, Some(HANDSHAKE_TIMEOUT));
            let err = r.recv().unwrap_err().to_string();
            assert!(err.contains("plaintext endpoint"), "got: {err}");
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        write_frame(&mut stream, &[SEALED_MARKER, 0, 0, 0]).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn trickled_frame_hits_the_deadline() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let start = Instant::now();
            let err = read_frame_bounded(&mut stream, Some(Duration::from_secs(10)));
            (start.elapsed(), err)
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        // Announce a 64-byte frame, then trickle one byte at a time —
        // slower than the deadline allows in total.
        stream.write_all(&64u32.to_le_bytes()).unwrap();
        let trickle_start = Instant::now();
        while trickle_start.elapsed() < FRAME_DEADLINE + Duration::from_secs(2) {
            if stream.write_all(&[0u8]).is_err() {
                break; // reader gave up and closed — expected
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        let (elapsed, result) = reader.join().unwrap();
        assert!(result.is_err(), "trickled frame must error");
        assert!(
            elapsed < FRAME_DEADLINE + Duration::from_secs(2),
            "reader must give up near the deadline, took {elapsed:?}"
        );
    }

    #[test]
    fn incremental_decoder_matches_blocking_framing() {
        let msgs = [
            Msg::HealthReq,
            Msg::Ping { nonce: 42 },
            Msg::Submit {
                id: 7,
                kind: crate::mmpu::FunctionKind::Add(8),
                a: 123,
                b: 45,
                trace: 0,
            },
            Msg::Shutdown,
        ];
        // Sealed: encode with the tx seal, trickle the bytes one at a
        // time through a decoder holding the rx seal.
        let psk = Psk::from_material(b"k").unwrap();
        let keys = derive_keys(&psk, &[5u8; 32], &[6u8; 32]);
        let mut tx = Some(keys.c2s);
        let mut dec = FrameDecoder::new(Some(derive_keys(&psk, &[5u8; 32], &[6u8; 32]).c2s));
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&encode_frame(m, &mut tx).unwrap());
        }
        let mut got = Vec::new();
        for b in &wire {
            dec.push(std::slice::from_ref(b));
            while let Some(m) = dec.try_next().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got, msgs);
        assert!(!dec.mid_frame(), "no partial frame left over");

        // Plaintext: same trickle, no seal.
        let mut dec = FrameDecoder::new(None);
        for m in &msgs {
            dec.push(&encode_frame(m, &mut None).unwrap());
        }
        let mut got = Vec::new();
        while let Some(m) = dec.try_next().unwrap() {
            got.push(m);
        }
        assert_eq!(got, msgs);
    }

    #[test]
    fn incremental_decoder_rejects_bad_frames_like_the_reader() {
        // Implausible length.
        let mut dec = FrameDecoder::new(None);
        dec.push(&(MAX_FRAME as u32 + 64).to_le_bytes());
        dec.push(&[0u8; 8]);
        let err = dec.try_next().unwrap_err().to_string();
        assert!(err.contains("implausible frame length"), "got: {err}");

        // Sealed marker on a plaintext decoder.
        let mut dec = FrameDecoder::new(None);
        dec.push(&4u32.to_le_bytes());
        dec.push(&[SEALED_MARKER, 0, 0, 0]);
        let err = dec.try_next().unwrap_err().to_string();
        assert!(err.contains("plaintext endpoint"), "got: {err}");

        // Tampered sealed frame fails the MAC exactly as Seal::open does.
        let psk = Psk::from_material(b"k").unwrap();
        let mut tx = Some(derive_keys(&psk, &[7u8; 32], &[8u8; 32]).c2s);
        let mut dec = FrameDecoder::new(Some(derive_keys(&psk, &[7u8; 32], &[8u8; 32]).c2s));
        let mut frame = encode_frame(&Msg::HealthReq, &mut tx).unwrap();
        let last = frame.len() - 1;
        frame[last] ^= 1;
        dec.push(&frame);
        assert!(dec.try_next().is_err(), "tampered frame must fail");
    }
}
