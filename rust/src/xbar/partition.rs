//! Dynamic crossbar partitions (paper §II-A / Fig. 1c).
//!
//! Transistors divide the crossbar into electrically isolated segments so
//! multiple in-row (in-column) gates can fire in the same row (column)
//! simultaneously. A partition configuration is a sorted list of segment
//! start lines; reconfiguration is dynamic (FELIX-style) and costs one
//! cycle (tracked by the crossbar stats).

use anyhow::{ensure, Result};

/// A partition configuration over `lines` lines (columns for in-row ops).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Partitions {
    /// Sorted segment start indices; always begins with 0.
    starts: Vec<u32>,
    lines: u32,
}

impl Partitions {
    /// Single segment spanning everything (no partitioning).
    pub fn whole(lines: u32) -> Self {
        Self { starts: vec![0], lines }
    }

    /// Segments of fixed `width` (the MultPIM configuration: one
    /// partition per bit position).
    pub fn uniform(lines: u32, width: u32) -> Self {
        Self::try_uniform(lines, width).expect("invalid uniform partitioning")
    }

    /// Fallible [`Partitions::uniform`]: rejects a zero-width segment
    /// grid and a grid wider than the line count with explicit errors.
    pub fn try_uniform(lines: u32, width: u32) -> Result<Self> {
        ensure!(width > 0, "partition width must be nonzero");
        ensure!(
            width <= lines,
            "partition width {width} exceeds {lines} lines"
        );
        let starts = (0..lines).step_by(width as usize).collect();
        Ok(Self { starts, lines })
    }

    /// Arbitrary boundaries. `starts` must be sorted, unique, begin at 0.
    pub fn new(lines: u32, starts: Vec<u32>) -> Self {
        Self::try_new(lines, starts).expect("invalid partition boundaries")
    }

    /// Fallible [`Partitions::new`]: every malformed segment list — empty,
    /// not starting at 0 (non-covering), zero-width or out-of-order
    /// (duplicate/decreasing starts, i.e. overlapping segments), or a
    /// start past the line count — is an explicit `Err`, so callers
    /// building configurations from untrusted data (schedulers, the
    /// wire) can reject instead of aborting.
    pub fn try_new(lines: u32, starts: Vec<u32>) -> Result<Self> {
        ensure!(lines > 0, "partitions need at least one line");
        ensure!(!starts.is_empty(), "partition start list is empty");
        ensure!(
            starts[0] == 0,
            "first segment must start at 0 (got {}): segments would not cover the array",
            starts[0]
        );
        for w in starts.windows(2) {
            ensure!(
                w[0] < w[1],
                "segment starts must be strictly increasing ({} then {}): \
                 zero-width or overlapping segment",
                w[0],
                w[1]
            );
        }
        let last = *starts.last().unwrap();
        ensure!(last < lines, "segment start {last} beyond {lines} lines");
        Ok(Self { starts, lines })
    }

    /// This configuration refined by a uniform grid of (at most)
    /// `segments` equal segments: the union of both boundary sets. Every
    /// existing boundary is preserved, so any op group that was legal
    /// under `self` stays legal — disjoint coarse partition ranges map
    /// to disjoint refined ranges (§Perf list scheduling builds its
    /// packing configuration this way).
    pub fn refined_with_grid(&self, segments: u32) -> Partitions {
        let width = (self.lines / segments.max(1)).max(1);
        let mut starts = self.starts.clone();
        starts.extend((0..self.lines).step_by(width as usize));
        starts.sort_unstable();
        starts.dedup();
        Self { starts, lines: self.lines }
    }

    pub fn count(&self) -> usize {
        self.starts.len()
    }

    pub fn lines(&self) -> u32 {
        self.lines
    }

    /// Index of the partition containing `line`.
    pub fn partition_of(&self, line: u32) -> usize {
        assert!(line < self.lines, "line {line} out of range");
        match self.starts.binary_search(&line) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// [start, end) of partition `i`.
    pub fn bounds(&self, i: usize) -> (u32, u32) {
        let start = self.starts[i];
        let end = self.starts.get(i + 1).copied().unwrap_or(self.lines);
        (start, end)
    }

    /// Does the closed line span [lo, hi] sit inside one partition?
    /// Returns that partition's index, or None if it crosses a boundary.
    pub fn containing(&self, lo: u32, hi: u32) -> Option<usize> {
        let p = self.partition_of(lo);
        let (_, end) = self.bounds(p);
        if hi < end {
            Some(p)
        } else {
            None
        }
    }

    /// Alias of [`Partitions::containing`] under the scheduler's
    /// vocabulary: whether a driver span stays within one electrically
    /// isolated segment.
    pub fn span_within(&self, lo: u32, hi: u32) -> Option<usize> {
        self.containing(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_is_one_partition() {
        let p = Partitions::whole(64);
        assert_eq!(p.count(), 1);
        assert_eq!(p.partition_of(0), 0);
        assert_eq!(p.partition_of(63), 0);
        assert_eq!(p.bounds(0), (0, 64));
        assert_eq!(p.containing(3, 60), Some(0));
    }

    #[test]
    fn uniform_partitions() {
        let p = Partitions::uniform(64, 16);
        assert_eq!(p.count(), 4);
        assert_eq!(p.partition_of(15), 0);
        assert_eq!(p.partition_of(16), 1);
        assert_eq!(p.bounds(3), (48, 64));
        assert_eq!(p.containing(16, 31), Some(1));
        assert_eq!(p.containing(15, 16), None, "span crosses a boundary");
    }

    #[test]
    fn custom_boundaries() {
        let p = Partitions::new(100, vec![0, 10, 50]);
        assert_eq!(p.count(), 3);
        assert_eq!(p.bounds(0), (0, 10));
        assert_eq!(p.bounds(2), (50, 100));
        assert_eq!(p.partition_of(49), 1);
    }

    #[test]
    #[should_panic]
    fn must_start_at_zero() {
        Partitions::new(10, vec![1, 5]);
    }

    #[test]
    #[should_panic]
    fn line_oob_panics() {
        Partitions::whole(10).partition_of(10);
    }

    #[test]
    fn try_new_rejects_malformed_segment_lists() {
        // Empty list: nothing covers the array.
        assert!(Partitions::try_new(10, vec![]).is_err());
        // Non-covering: the prefix [0, first) belongs to no segment.
        assert!(Partitions::try_new(10, vec![1, 5]).is_err());
        // Zero-width segment (duplicate start).
        assert!(Partitions::try_new(10, vec![0, 4, 4]).is_err());
        // Overlapping (decreasing) starts.
        assert!(Partitions::try_new(10, vec![0, 6, 3]).is_err());
        // Start at / beyond the line count.
        assert!(Partitions::try_new(10, vec![0, 10]).is_err());
        assert!(Partitions::try_new(10, vec![0, 11]).is_err());
        // Degenerate array.
        assert!(Partitions::try_new(0, vec![0]).is_err());
        // The well-formed case still round-trips.
        let p = Partitions::try_new(10, vec![0, 4, 9]).unwrap();
        assert_eq!(p.count(), 3);
        assert_eq!(p.bounds(2), (9, 10));
    }

    #[test]
    fn try_uniform_rejects_degenerate_widths() {
        assert!(Partitions::try_uniform(16, 0).is_err());
        assert!(Partitions::try_uniform(16, 17).is_err());
        assert_eq!(Partitions::try_uniform(16, 16).unwrap().count(), 1);
        // Non-dividing width: a short tail segment, still covering.
        let p = Partitions::try_uniform(10, 4).unwrap();
        assert_eq!(p.count(), 3);
        assert_eq!(p.bounds(2), (8, 10));
    }

    #[test]
    fn partition_of_and_span_within_pin_boundaries() {
        let p = Partitions::new(100, vec![0, 10, 50]);
        // partition_of at every segment's first and last line.
        assert_eq!(p.partition_of(0), 0);
        assert_eq!(p.partition_of(9), 0);
        assert_eq!(p.partition_of(10), 1);
        assert_eq!(p.partition_of(49), 1);
        assert_eq!(p.partition_of(50), 2);
        assert_eq!(p.partition_of(99), 2);
        // span_within: full-segment spans, single lines at boundaries,
        // and one-past spans that cross.
        assert_eq!(p.span_within(0, 9), Some(0));
        assert_eq!(p.span_within(10, 49), Some(1));
        assert_eq!(p.span_within(50, 99), Some(2));
        assert_eq!(p.span_within(9, 9), Some(0));
        assert_eq!(p.span_within(10, 10), Some(1));
        assert_eq!(p.span_within(9, 10), None, "crosses the 10 boundary");
        assert_eq!(p.span_within(49, 50), None, "crosses the 50 boundary");
        assert_eq!(p.span_within(0, 99), None, "spans every segment");
    }

    #[test]
    fn grid_refinement_preserves_existing_boundaries() {
        let base = Partitions::new(64, vec![0, 10, 40]);
        let fine = base.refined_with_grid(8); // width 8 grid
        // Every base boundary survives, plus the grid lines.
        for b in [0u32, 10, 40] {
            assert_eq!(fine.bounds(fine.partition_of(b)).0, b, "boundary {b} kept");
        }
        assert_eq!(fine.lines(), 64);
        assert!(fine.count() >= base.count());
        // A span legal under base that stays inside one fine segment is
        // still legal; spans disjoint under base remain disjoint (their
        // refined partition ranges cannot merge).
        assert_eq!(fine.span_within(40, 47), Some(fine.partition_of(40)));
        // Refinement with more segments than lines degrades to width 1.
        let unit = base.refined_with_grid(1000);
        assert_eq!(unit.count(), 64);
    }
}
