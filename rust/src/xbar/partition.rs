//! Dynamic crossbar partitions (paper §II-A / Fig. 1c).
//!
//! Transistors divide the crossbar into electrically isolated segments so
//! multiple in-row (in-column) gates can fire in the same row (column)
//! simultaneously. A partition configuration is a sorted list of segment
//! start lines; reconfiguration is dynamic (FELIX-style) and costs one
//! cycle (tracked by the crossbar stats).

/// A partition configuration over `lines` lines (columns for in-row ops).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partitions {
    /// Sorted segment start indices; always begins with 0.
    starts: Vec<u32>,
    lines: u32,
}

impl Partitions {
    /// Single segment spanning everything (no partitioning).
    pub fn whole(lines: u32) -> Self {
        Self { starts: vec![0], lines }
    }

    /// Segments of fixed `width` (the MultPIM configuration: one
    /// partition per bit position).
    pub fn uniform(lines: u32, width: u32) -> Self {
        assert!(width > 0 && width <= lines);
        let starts = (0..lines).step_by(width as usize).collect();
        Self { starts, lines }
    }

    /// Arbitrary boundaries. `starts` must be sorted, unique, begin at 0.
    pub fn new(lines: u32, starts: Vec<u32>) -> Self {
        assert!(!starts.is_empty() && starts[0] == 0, "first segment must start at 0");
        assert!(starts.windows(2).all(|w| w[0] < w[1]), "starts must be strictly increasing");
        assert!(*starts.last().unwrap() < lines, "start beyond line count");
        Self { starts, lines }
    }

    pub fn count(&self) -> usize {
        self.starts.len()
    }

    pub fn lines(&self) -> u32 {
        self.lines
    }

    /// Index of the partition containing `line`.
    pub fn partition_of(&self, line: u32) -> usize {
        assert!(line < self.lines, "line {line} out of range");
        match self.starts.binary_search(&line) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// [start, end) of partition `i`.
    pub fn bounds(&self, i: usize) -> (u32, u32) {
        let start = self.starts[i];
        let end = self.starts.get(i + 1).copied().unwrap_or(self.lines);
        (start, end)
    }

    /// Does the closed line span [lo, hi] sit inside one partition?
    /// Returns that partition's index, or None if it crosses a boundary.
    pub fn containing(&self, lo: u32, hi: u32) -> Option<usize> {
        let p = self.partition_of(lo);
        let (_, end) = self.bounds(p);
        if hi < end {
            Some(p)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_is_one_partition() {
        let p = Partitions::whole(64);
        assert_eq!(p.count(), 1);
        assert_eq!(p.partition_of(0), 0);
        assert_eq!(p.partition_of(63), 0);
        assert_eq!(p.bounds(0), (0, 64));
        assert_eq!(p.containing(3, 60), Some(0));
    }

    #[test]
    fn uniform_partitions() {
        let p = Partitions::uniform(64, 16);
        assert_eq!(p.count(), 4);
        assert_eq!(p.partition_of(15), 0);
        assert_eq!(p.partition_of(16), 1);
        assert_eq!(p.bounds(3), (48, 64));
        assert_eq!(p.containing(16, 31), Some(1));
        assert_eq!(p.containing(15, 16), None, "span crosses a boundary");
    }

    #[test]
    fn custom_boundaries() {
        let p = Partitions::new(100, vec![0, 10, 50]);
        assert_eq!(p.count(), 3);
        assert_eq!(p.bounds(0), (0, 10));
        assert_eq!(p.bounds(2), (50, 100));
        assert_eq!(p.partition_of(49), 1);
    }

    #[test]
    #[should_panic]
    fn must_start_at_zero() {
        Partitions::new(10, vec![1, 5]);
    }

    #[test]
    #[should_panic]
    fn line_oob_panics() {
        Partitions::whole(10).partition_of(10);
    }
}
