//! Memristor device / crossbar timing-energy model.
//!
//! The paper evaluates at the architecture level (probabilities per gate /
//! per access), but latency and energy accounting need physical constants.
//! Values follow the VTEAM-style parameters used across the mMPU
//! literature (MAGIC/FELIX/MultPIM evaluations): ~1 ns gate pulses, ~fJ
//! switching energy, Ron/Roff two-decade separation.

/// Physical device + array parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceModel {
    /// Low-resistance ("1") state, ohms.
    pub r_on: f64,
    /// High-resistance ("0") state, ohms.
    pub r_off: f64,
    /// Gate/write pulse width — one crossbar cycle, nanoseconds.
    pub cycle_ns: f64,
    /// Energy to switch one memristor's state, picojoules.
    pub e_switch_pj: f64,
    /// Energy of half-selected cells per gate instance, picojoules.
    pub e_half_select_pj: f64,
    /// Lognormal sigma of the resistance distributions (variability) —
    /// used to *derive* an indicative p_gate for documentation/examples.
    pub sigma_r: f64,
}

impl DeviceModel {
    pub fn default_rram() -> Self {
        Self {
            r_on: 1e3,
            r_off: 1e5,
            cycle_ns: 1.0,
            e_switch_pj: 0.1,
            e_half_select_pj: 0.01,
            sigma_r: 0.15,
        }
    }

    /// Clock frequency implied by the cycle time, MHz.
    pub fn freq_mhz(&self) -> f64 {
        1e3 / self.cycle_ns
    }

    /// Rough probability that resistance variability flips a gate output:
    /// the overlap of the lognormal Ron / Roff distributions at the
    /// read margin (geometric mean of Ron, Roff). This is *indicative* —
    /// the reliability experiments sweep p_gate explicitly.
    pub fn derived_p_gate(&self) -> f64 {
        let margin = (self.r_on.ln() + self.r_off.ln()) / 2.0;
        // P[lognormal(ln r_on, sigma) > margin] = Q(d/sigma), d in log-space.
        let d = (margin - self.r_on.ln()) / self.sigma_r;
        q_function(d)
    }

    /// Energy of one micro-op: `switched` state transitions plus
    /// half-select overhead across `instances` gate instances.
    pub fn op_energy_pj(&self, switched: u64, instances: u64) -> f64 {
        switched as f64 * self.e_switch_pj + instances as f64 * self.e_half_select_pj
    }
}

impl Default for DeviceModel {
    fn default() -> Self {
        Self::default_rram()
    }
}

/// Gaussian tail Q(x) via the shared Abramowitz-Stegun erfc.
fn q_function(x: f64) -> f64 {
    0.5 * crate::util::stats::erfc(x / std::f64::consts::SQRT_2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let d = DeviceModel::default_rram();
        assert!(d.r_off > d.r_on);
        assert_eq!(d.freq_mhz(), 1000.0);
    }

    #[test]
    fn q_function_reference_points() {
        assert!((q_function(0.0) - 0.5).abs() < 1e-6);
        assert!(q_function(6.0) < 1e-8);
        assert!((q_function(-6.0) - 1.0).abs() < 1e-8);
    }

    #[test]
    fn derived_p_gate_decreases_with_margin() {
        let tight = DeviceModel { sigma_r: 0.5, ..DeviceModel::default_rram() };
        let loose = DeviceModel { sigma_r: 0.1, ..DeviceModel::default_rram() };
        assert!(loose.derived_p_gate() < tight.derived_p_gate());
        assert!(tight.derived_p_gate() < 0.5);
    }

    #[test]
    fn energy_accumulates() {
        let d = DeviceModel::default_rram();
        let e = d.op_energy_pj(100, 1024);
        assert!((e - (100.0 * 0.1 + 1024.0 * 0.01)).abs() < 1e-9);
    }
}
