//! The memristive crossbar substrate: stateful gates, partitions, the
//! cycle-accurate array simulator and the device timing/energy model.

pub mod crossbar;
pub mod device;
pub mod gate;
pub mod partition;

pub use crossbar::{Crossbar, XbarStats};
pub use device::DeviceModel;
pub use gate::Gate;
pub use partition::Partitions;
