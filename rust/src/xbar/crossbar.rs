//! The memristive crossbar array simulator — substrate S2.
//!
//! Executes micro-op programs with cycle accounting, partition validation,
//! and soft-error injection. The dominant path — an in-row gate across all
//! rows — runs word-parallel on the column-major `BitMatrix` (64 rows per
//! bitwise op); error injection uses geometric skipping, so reliability
//! simulation stays O(lanes * p) per gate.
//!
//! §Perf: execution is plan-compiled. [`Crossbar::run_program`] is a thin
//! wrapper that compiles the program against the current shape/partitions
//! (`isa::CompiledPlan`) and runs it through the allocation-free
//! [`Crossbar::run_plan`] interpreter; callers on the serving hot path
//! compile once and call `run_plan` directly. The pre-compilation
//! per-step path survives as [`Crossbar::run_program_uncompiled`] — the
//! bit-exact reference the equivalence property tests compare against.
//! In-column gates run word-parallel over 64-column gather/scatter tiles
//! (the transpose orientation of the in-row word path).

use anyhow::{ensure, Result};

use crate::errs::Injector;
use crate::isa::microop::{Dir, MicroOp};
use crate::isa::plan::{validate_step_concurrency, CompiledPlan, PlanOp, ScheduleConfig};
use crate::isa::program::{Program, Step};
use crate::util::bitmat::BitMatrix;
use crate::xbar::gate::Gate;

use super::device::DeviceModel;
use super::partition::Partitions;

/// Cycle / energy / operation statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct XbarStats {
    /// Crossbar cycles elapsed (each `Step` = 1 cycle; reconfigs = 1).
    pub cycles: u64,
    /// Logic micro-ops executed.
    pub logic_ops: u64,
    /// Init/write micro-ops executed.
    pub init_ops: u64,
    /// Gate *instances* = micro-ops x lanes (soft-error sites).
    pub gate_instances: u64,
    /// Memristor state transitions (energy proxy).
    pub switched_bits: u64,
    /// Partition reconfigurations.
    pub reconfigs: u64,
    /// Accumulated energy, picojoules.
    pub energy_pj: f64,
}

impl XbarStats {
    pub fn add(&mut self, other: &XbarStats) {
        self.cycles += other.cycles;
        self.logic_ops += other.logic_ops;
        self.init_ops += other.init_ops;
        self.gate_instances += other.gate_instances;
        self.switched_bits += other.switched_bits;
        self.reconfigs += other.reconfigs;
        self.energy_pj += other.energy_pj;
    }
}

/// A single crossbar array with stateful-logic execution.
#[derive(Clone, Debug)]
pub struct Crossbar {
    state: BitMatrix,
    /// Partitioning of columns (constrains in-row ops).
    col_parts: Partitions,
    /// Partitioning of rows (constrains in-column ops).
    row_parts: Partitions,
    pub device: DeviceModel,
    pub stats: XbarStats,
    /// All-zero word buffer (operand stand-in for arity-0 gates).
    zeros: Vec<u64>,
}

impl Crossbar {
    pub fn new(rows: usize, cols: usize) -> Self {
        let state = BitMatrix::zeros(rows, cols);
        let wpc = state.words_per_col();
        Self {
            state,
            col_parts: Partitions::whole(cols as u32),
            row_parts: Partitions::whole(rows as u32),
            device: DeviceModel::default_rram(),
            stats: XbarStats::default(),
            zeros: vec![0; wpc],
        }
    }

    pub fn rows(&self) -> usize {
        self.state.rows()
    }

    pub fn cols(&self) -> usize {
        self.state.cols()
    }

    pub fn state(&self) -> &BitMatrix {
        &self.state
    }

    pub fn state_mut(&mut self) -> &mut BitMatrix {
        &mut self.state
    }

    pub fn get(&self, r: usize, c: usize) -> bool {
        self.state.get(r, c)
    }

    /// Direct write (memory interface, not stateful logic). One cycle per
    /// call; write failures apply when an injector is given.
    pub fn write_bit(&mut self, r: usize, c: usize, v: bool, inj: Option<&mut Injector>) {
        let mut v = v;
        if let Some(inj) = inj {
            let mut fail = false;
            inj.write_fails(1, |_| fail = true);
            if fail {
                v = !v;
            }
        }
        if self.state.get(r, c) != v {
            self.stats.switched_bits += 1;
        }
        self.state.set(r, c, v);
        self.stats.cycles += 1;
    }

    /// Reconfigure column partitions (1 cycle, FELIX-style dynamic).
    pub fn set_col_partitions(&mut self, parts: Partitions) {
        assert_eq!(parts.lines() as usize, self.cols());
        self.col_parts = parts;
        self.stats.reconfigs += 1;
        self.stats.cycles += 1;
    }

    pub fn set_row_partitions(&mut self, parts: Partitions) {
        assert_eq!(parts.lines() as usize, self.rows());
        self.row_parts = parts;
        self.stats.reconfigs += 1;
        self.stats.cycles += 1;
    }

    pub fn col_partitions(&self) -> &Partitions {
        &self.col_parts
    }

    pub fn row_partitions(&self) -> &Partitions {
        &self.row_parts
    }

    /// Compile a program against this crossbar's current shape and
    /// partition configuration (§Perf: validate once, run many).
    pub fn compile_plan(&self, prog: &Program) -> Result<CompiledPlan> {
        CompiledPlan::compile(prog, self.rows(), self.cols(), &self.col_parts, &self.row_parts)
    }

    /// Compile with the §Perf list scheduler: packs independent
    /// micro-ops into shared cycles over a column grid refined from this
    /// crossbar's current configuration. A scheduled plan may *require*
    /// that refined grid — consult `required_col_partitions()` and
    /// `set_col_partitions` before `run_plan`, so the reconfiguration
    /// cycle stays visible in the stats. Falls back to the serial plan
    /// when packing removes no cycles.
    pub fn compile_plan_scheduled(
        &self,
        prog: &Program,
        sched: ScheduleConfig,
    ) -> Result<CompiledPlan> {
        CompiledPlan::compile_scheduled(
            prog,
            self.rows(),
            self.cols(),
            &self.col_parts,
            &self.row_parts,
            sched,
        )
    }

    /// Execute one cycle (a `Step` of concurrent micro-ops) with
    /// execution-time validation — the legacy per-step path.
    pub fn apply_step(&mut self, step: &Step, mut inj: Option<&mut Injector>) -> Result<()> {
        ensure!(!step.ops.is_empty(), "empty step");
        if step.ops.len() > 1 {
            validate_step_concurrency(&step.ops, &self.col_parts, &self.row_parts)?;
        }
        for op in &step.ops {
            self.exec_op(op, inj.as_deref_mut())?;
        }
        self.stats.cycles += 1;
        Ok(())
    }

    /// Execute a whole program: compiles against the current
    /// shape/partitions, then runs the plan. One-shot callers keep this
    /// convenience; hot paths should `compile_plan` once and `run_plan`.
    pub fn run_program(&mut self, prog: &Program, inj: Option<&mut Injector>) -> Result<()> {
        let plan = self.compile_plan(prog)?;
        self.run_plan(&plan, inj)
    }

    /// Execute a whole program through the pre-§Perf per-step interpreter
    /// (re-validates concurrency every cycle). Kept as the bit-exact
    /// reference for the plan-equivalence property tests.
    pub fn run_program_uncompiled(
        &mut self,
        prog: &Program,
        mut inj: Option<&mut Injector>,
    ) -> Result<()> {
        for step in &prog.steps {
            self.apply_step(step, inj.as_deref_mut())?;
        }
        Ok(())
    }

    /// Execute a compiled plan: the allocation-free hot loop. Each step
    /// slice is one *bundle* — a cycle's worth of concurrent ops (a
    /// serial plan is the 1-op-bundle case; a scheduled plan packs
    /// several, see `compile_plan_scheduled`). The plan must have been
    /// compiled for this crossbar's shape, and — when it contains
    /// concurrent bundles — for its current partition configuration
    /// (checked cheaply here).
    pub fn run_plan(&mut self, plan: &CompiledPlan, mut inj: Option<&mut Injector>) -> Result<()> {
        ensure!(
            plan.rows() == self.rows() && plan.cols() == self.cols(),
            "plan {} compiled for {}x{}, crossbar is {}x{}",
            plan.name,
            plan.rows(),
            plan.cols(),
            self.rows(),
            self.cols()
        );
        if let Some(parts) = plan.required_col_partitions() {
            ensure!(
                parts == &self.col_parts,
                "plan {} compiled for a different column-partition configuration",
                plan.name
            );
        }
        if let Some(parts) = plan.required_row_partitions() {
            ensure!(
                parts == &self.row_parts,
                "plan {} compiled for a different row-partition configuration",
                plan.name
            );
        }
        for ops in plan.step_ops() {
            for op in ops {
                match op.dir {
                    Dir::InRow => self.exec_in_row_resolved(op, inj.as_deref_mut()),
                    Dir::InCol => self.exec_in_col_resolved(op, inj.as_deref_mut()),
                }
            }
            self.stats.cycles += 1;
        }
        Ok(())
    }

    fn exec_op(&mut self, op: &MicroOp, inj: Option<&mut Injector>) -> Result<()> {
        let resolved = match op.dir {
            Dir::InRow => PlanOp::resolve_in_row(op, self.rows(), self.cols())?,
            Dir::InCol => PlanOp::resolve_in_col(op, self.rows(), self.cols())?,
        };
        match op.dir {
            Dir::InRow => self.exec_in_row_resolved(&resolved, inj),
            Dir::InCol => self.exec_in_col_resolved(&resolved, inj),
        }
        Ok(())
    }

    /// Row-parallel in-row gate: word-wide over the packed columns, all
    /// bounds/lanes/masks pre-resolved in the [`PlanOp`].
    fn exec_in_row_resolved(&mut self, op: &PlanOp, mut inj: Option<&mut Injector>) {
        let (s, e) = (op.s as usize, op.e as usize);
        let lanes = e - s;
        let arity = op.arity as usize;
        // Indirect input drift: accessed input bits may flip *in place*
        // (read/logic disturb — paper §II-B1).
        if let Some(inj) = inj.as_deref_mut() {
            if inj.model.p_input > 0.0 && arity > 0 {
                let input_cols = [op.a as usize, op.b as usize, op.c as usize];
                let state = &mut self.state;
                inj.input_drifts(arity * lanes, |i| {
                    let which = i / lanes;
                    let r = s + (i % lanes);
                    state.flip(r, input_cols[which]);
                });
            }
        }

        // Word-parallel gate application, copy-free: the output column
        // never aliases an input (MicroOp invariant), so we take three
        // shared column views + one mutable (§Perf: this replaced three
        // per-op scratch memcpys; lane masks are precompiled).
        let (w_lo, w_hi) = (op.w_lo as usize, op.w_hi as usize);
        let (first_mask, last_mask) = (op.first_mask, op.last_mask);
        let mut switched = 0u64;
        let gate = op.gate;
        let mut apply = |col_a: &[u64], col_b: &[u64], col_c: &[u64], out_col: &mut [u64]| {
            for wi in w_lo..=w_hi {
                let mut mask = u64::MAX;
                if wi == w_lo {
                    mask &= first_mask;
                }
                if wi == w_hi {
                    mask &= last_mask;
                }
                let prev = out_col[wi];
                let val = gate.eval_word(col_a[wi], col_b[wi], col_c[wi], prev);
                let next = (prev & !mask) | (val & mask);
                switched += (prev ^ next).count_ones() as u64;
                out_col[wi] = next;
            }
        };
        if arity == 0 {
            // SET1 / SET0 / NOP read no operands (and their a/b/c mirror
            // `out` by convention, so the aliasing check must be skipped).
            let z = &self.zeros;
            let out_col = self.state.col_mut(op.out as usize);
            apply(z, z, z, out_col);
        } else {
            let (ca, cb, cc, out_col) =
                self.state.cols_gate(op.a as usize, op.b as usize, op.c as usize, op.out as usize);
            apply(ca, cb, cc, out_col);
        }

        // Direct errors on the produced output bits.
        if let Some(inj) = inj {
            if gate.is_logic() {
                let out = op.out as usize;
                let state = &mut self.state;
                let mut flipped = 0u64;
                inj.gate_flips(lanes, |i| {
                    state.flip(s + i, out);
                    flipped += 1;
                });
                switched += flipped; // error flips also switch state
            } else if gate.is_init() {
                let out = op.out as usize;
                let state = &mut self.state;
                inj.write_fails(lanes, |i| {
                    state.flip(s + i, out);
                });
            }
        }

        self.account(gate, lanes as u64, switched);
    }

    /// Column-parallel in-column gate, word-parallel over 64-column
    /// gather/scatter tiles (§Perf: replaced the per-column bit path; the
    /// four operand rows of a tile are gathered into packed words, the
    /// gate evaluates 64 columns at once, and only *changed* output bits
    /// are scattered back).
    fn exec_in_col_resolved(&mut self, op: &PlanOp, inj: Option<&mut Injector>) {
        let (s, e) = (op.s as usize, op.e as usize);
        let lanes = e - s;
        let (ra, rb, rc, ro) = (op.a as usize, op.b as usize, op.c as usize, op.out as usize);
        let (wa, ba) = (ra / 64, ra % 64);
        let (wb, bb) = (rb / 64, rb % 64);
        let (wc, bc) = (rc / 64, rc % 64);
        let (wo, bo) = (ro / 64, ro % 64);
        let arity = op.arity as usize;
        let gate = op.gate;
        let mut switched = 0u64;
        let mut col = s;
        while col < e {
            let tile = (e - col).min(64);
            let (mut aw, mut bw, mut cw, mut pw) = (0u64, 0u64, 0u64, 0u64);
            for j in 0..tile {
                let packed = self.state.col(col + j);
                aw |= ((packed[wa] >> ba) & 1) << j;
                bw |= ((packed[wb] >> bb) & 1) << j;
                cw |= ((packed[wc] >> bc) & 1) << j;
                pw |= ((packed[wo] >> bo) & 1) << j;
            }
            let val = gate.eval_word(aw, bw, cw, pw);
            let tile_mask = if tile == 64 { u64::MAX } else { (1u64 << tile) - 1 };
            let mut diff = (pw ^ val) & tile_mask;
            switched += diff.count_ones() as u64;
            while diff != 0 {
                let j = diff.trailing_zeros() as usize;
                diff &= diff - 1;
                self.state.flip(ro, col + j);
            }
            col += tile;
        }
        if let Some(inj) = inj {
            // Indirect drift on accessed inputs.
            if inj.model.p_input > 0.0 && arity > 0 {
                let input_rows = [ra, rb, rc];
                let state = &mut self.state;
                inj.input_drifts(arity * lanes, |i| {
                    let which = i / lanes;
                    let col = s + (i % lanes);
                    state.flip(input_rows[which], col);
                });
            }
            if gate.is_logic() {
                let state = &mut self.state;
                let mut flipped = 0u64;
                inj.gate_flips(lanes, |i| {
                    state.flip(ro, s + i);
                    flipped += 1;
                });
                switched += flipped;
            } else if gate.is_init() {
                let state = &mut self.state;
                inj.write_fails(lanes, |i| {
                    state.flip(ro, s + i);
                });
            }
        }
        self.account(gate, lanes as u64, switched);
    }

    fn account(&mut self, gate: Gate, lanes: u64, switched: u64) {
        if gate.is_logic() {
            self.stats.logic_ops += 1;
            self.stats.gate_instances += lanes;
        } else if gate.is_init() {
            self.stats.init_ops += 1;
        }
        self.stats.switched_bits += switched;
        self.stats.energy_pj += self.device.op_energy_pj(switched, lanes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::errs::ErrorModel;
    use crate::isa::microop::LaneRange;
    use crate::isa::program::RowProgramBuilder;

    fn xbar_with_inputs(rows: usize, cols: usize, f: impl Fn(usize, usize) -> bool) -> Crossbar {
        let mut x = Crossbar::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                x.state_mut().set(r, c, f(r, c));
            }
        }
        x
    }

    #[test]
    fn in_row_nor_all_rows() {
        // Fig 1(a): the same NOR in every row, one cycle.
        let mut x = xbar_with_inputs(130, 8, |r, c| match c {
            0 => r % 2 == 0,
            1 => r % 3 == 0,
            _ => false,
        });
        x.apply_step(&Step::one(MicroOp::row(Gate::Nor2, &[0, 1], 2)), None).unwrap();
        for r in 0..130 {
            let want = !(r % 2 == 0 || r % 3 == 0);
            assert_eq!(x.get(r, 2), want, "row {r}");
        }
        assert_eq!(x.stats.cycles, 1);
        assert_eq!(x.stats.gate_instances, 130);
        assert_eq!(x.stats.logic_ops, 1);
    }

    #[test]
    fn in_col_nor_all_cols() {
        // Fig 1(b): the same NOR in every column, one cycle.
        let mut x = xbar_with_inputs(8, 70, |r, c| match r {
            0 => c % 2 == 0,
            1 => c % 5 == 0,
            _ => false,
        });
        x.apply_step(&Step::one(MicroOp::col(Gate::Nor2, &[0, 1], 2)), None).unwrap();
        for c in 0..70 {
            let want = !(c % 2 == 0 || c % 5 == 0);
            assert_eq!(x.get(2, c), want, "col {c}");
        }
        assert_eq!(x.stats.gate_instances, 70);
    }

    #[test]
    fn in_col_word_tiles_match_scalar_reference() {
        // The 64-column tile path against a per-bit reference, across
        // tile boundaries (150 cols), high row indices (word 1+ of the
        // packed columns), every gate, and a restricted lane range.
        let rows = 130;
        let cols = 150;
        let mut rng = crate::util::rng::Pcg64::new(9, 0);
        let init = BitMatrix::from_fn(rows, cols, |_, _| rng.bernoulli(0.5));
        for gate in [Gate::Nor2, Gate::Min3, Gate::Not, Gate::Imply, Gate::Set1, Gate::Set0] {
            let operands: Vec<u32> = match gate.arity() {
                0 => vec![],
                1 => vec![70],
                2 => vec![70, 3],
                _ => vec![70, 3, 127],
            };
            let op = MicroOp::col(gate, &operands, 100).over(LaneRange::new(5, 140));
            let mut x = Crossbar::new(rows, cols);
            *x.state_mut() = init.clone();
            x.apply_step(&Step::one(op), None).unwrap();
            for c in 0..cols {
                let expect = if (5..140).contains(&c) {
                    gate.eval_bit(
                        init.get(op.a as usize, c),
                        init.get(op.b as usize, c),
                        init.get(op.c as usize, c),
                        init.get(100, c),
                    )
                } else {
                    init.get(100, c)
                };
                assert_eq!(x.get(100, c), expect, "{gate:?} col {c}");
            }
        }
    }

    #[test]
    fn lane_range_restricts_rows() {
        // col 0 all zeros -> NOT writes 1, but only in lanes 10..20.
        let mut x = xbar_with_inputs(128, 4, |_, _| false);
        let op = MicroOp::row(Gate::Not, &[0], 1).over(LaneRange::new(10, 20));
        x.apply_step(&Step::one(op), None).unwrap();
        for r in 0..128 {
            assert_eq!(x.get(r, 1), (10..20).contains(&r), "row {r}");
        }
        assert_eq!(x.stats.gate_instances, 10);
    }

    #[test]
    fn partition_parallel_step() {
        // Fig 1(c): two NORs in the same row cycle, different partitions.
        let mut x = xbar_with_inputs(16, 8, |r, c| (r + c) % 2 == 0);
        x.set_col_partitions(Partitions::uniform(8, 4));
        let ops = vec![
            MicroOp::row(Gate::Nor2, &[0, 1], 2),
            MicroOp::row(Gate::Nor2, &[4, 5], 6),
        ];
        let cycles0 = x.stats.cycles;
        x.apply_step(&Step::many(ops), None).unwrap();
        assert_eq!(x.stats.cycles - cycles0, 1, "concurrent ops cost one cycle");
        for r in 0..16 {
            let a = (r) % 2 == 0;
            let b = (r + 1) % 2 == 0;
            assert_eq!(x.get(r, 2), !(a | b));
            assert_eq!(x.get(r, 6), !(a | b));
        }
    }

    #[test]
    fn cross_partition_op_rejected() {
        let mut x = Crossbar::new(8, 8);
        x.set_col_partitions(Partitions::uniform(8, 4));
        // NOR reading col 3 and col 4 crosses the boundary.
        let ops = vec![
            MicroOp::row(Gate::Nor2, &[3, 4], 5),
            MicroOp::row(Gate::Not, &[0], 1),
        ];
        assert!(x.apply_step(&Step::many(ops), None).is_err());
    }

    #[test]
    fn same_partition_concurrency_rejected() {
        let mut x = Crossbar::new(8, 8);
        let ops = vec![
            MicroOp::row(Gate::Not, &[0], 1),
            MicroOp::row(Gate::Not, &[2], 3),
        ];
        assert!(x.apply_step(&Step::many(ops), None).is_err(), "single partition");
        x.set_col_partitions(Partitions::uniform(8, 4));
        let ops = vec![
            MicroOp::row(Gate::Not, &[0], 1),
            MicroOp::row(Gate::Not, &[2], 3),
        ];
        assert!(x.apply_step(&Step::many(ops), None).is_err(), "same partition twice");
    }

    #[test]
    fn fan_out_not_is_one_cycle() {
        // Multi-output NOT: broadcast !col0 into one column per partition
        // (the MultPIM b_i broadcast pattern), one cycle, regardless of
        // partition boundaries.
        let mut x = xbar_with_inputs(16, 16, |r, c| c == 0 && r % 2 == 0);
        x.set_col_partitions(Partitions::uniform(16, 4));
        let ops: Vec<MicroOp> =
            (0..4).map(|k| MicroOp::row(Gate::Not, &[0], k * 4 + 1)).collect();
        let c0 = x.stats.cycles;
        x.apply_step(&Step::many(ops), None).unwrap();
        assert_eq!(x.stats.cycles - c0, 1);
        for r in 0..16 {
            for k in 0..4usize {
                assert_eq!(x.get(r, k * 4 + 1), r % 2 != 0, "row {r} part {k}");
            }
        }
    }

    #[test]
    fn fan_out_requires_distinct_outputs() {
        let mut x = Crossbar::new(8, 8);
        let ops = vec![
            MicroOp::row(Gate::Not, &[0], 1),
            MicroOp::row(Gate::Not, &[0], 1),
        ];
        assert!(x.apply_step(&Step::many(ops), None).is_err());
    }

    #[test]
    fn neighbor_span_allowed_when_disjoint() {
        // Two neighbor-transfer NOTs, each spanning its own pair of
        // partitions: {0,1} and {2,3} — legal in one cycle.
        let mut x = Crossbar::new(8, 16);
        x.set_col_partitions(Partitions::uniform(16, 4));
        let ops = vec![
            MicroOp::row(Gate::Not, &[4], 1),  // partition 1 -> 0
            MicroOp::row(Gate::Not, &[12], 9), // partition 3 -> 2
        ];
        x.apply_step(&Step::many(ops), None).unwrap();
        // Overlapping pairs {0,1} and {1,2} must be rejected.
        let ops = vec![
            MicroOp::row(Gate::Not, &[4], 1),
            MicroOp::row(Gate::Not, &[8], 5),
        ];
        assert!(x.apply_step(&Step::many(ops), None).is_err());
    }

    #[test]
    fn mixed_direction_concurrency_rejected() {
        let mut x = Crossbar::new(8, 8);
        let ops = vec![
            MicroOp::row(Gate::Not, &[0], 1),
            MicroOp::col(Gate::Not, &[2], 3),
        ];
        assert!(x.apply_step(&Step::many(ops), None).is_err());
    }

    #[test]
    fn imply_semantics() {
        // IMPLY: out' = !a | out (output doubles as operand).
        let mut x = xbar_with_inputs(4, 2, |r, c| match c {
            0 => r & 1 == 1,      // a = row parity
            _ => r & 2 == 2,      // out initial
        });
        x.apply_step(&Step::one(MicroOp::row(Gate::Imply, &[0], 1)), None).unwrap();
        for r in 0..4 {
            let a = r & 1 == 1;
            let out0 = r & 2 == 2;
            assert_eq!(x.get(r, 1), !a | out0, "row {r}");
        }
    }

    #[test]
    fn gate_error_injection_flips_outputs() {
        let mut x = xbar_with_inputs(1024, 4, |_, _| false);
        let mut inj = Injector::new(ErrorModel::direct_only(0.25), 42, 0);
        // NOR(0,0) = 1 everywhere; with p=0.25 about a quarter flip to 0.
        x.apply_step(&Step::one(MicroOp::row(Gate::Nor2, &[0, 1], 2)), Some(&mut inj))
            .unwrap();
        let ones = (0..1024).filter(|&r| x.get(r, 2)).count();
        let flips = inj.counters.gate_flips as usize;
        assert_eq!(ones, 1024 - flips);
        assert!(flips > 150 && flips < 370, "flips={flips}");
    }

    #[test]
    fn input_drift_corrupts_stored_inputs() {
        let mut x = xbar_with_inputs(512, 4, |_, c| c == 0);
        let mut inj = Injector::new(ErrorModel::indirect_only(0.1), 7, 0);
        x.apply_step(&Step::one(MicroOp::row(Gate::Not, &[0], 1)), Some(&mut inj)).unwrap();
        let zeros_in_input = (0..512).filter(|&r| !x.get(r, 0)).count();
        assert_eq!(zeros_in_input as u64, inj.counters.input_drifts);
        assert!(zeros_in_input > 20, "drift should have corrupted inputs");
    }

    #[test]
    fn run_program_full_adder_rowwise() {
        // The same 6-gate Min3 full adder as the python test, all 8 input
        // combinations at once (one per row).
        let mut x = Crossbar::new(8, 16);
        for r in 0..8 {
            x.state_mut().set(r, 0, (r >> 2) & 1 == 1);
            x.state_mut().set(r, 1, (r >> 1) & 1 == 1);
            x.state_mut().set(r, 2, r & 1 == 1);
        }
        let mut b = RowProgramBuilder::no_init("fa");
        b.gate(Gate::Min3, &[0, 1, 2], 3);
        b.gate(Gate::Not, &[3], 4);
        b.gate(Gate::Min3, &[0, 1, 3], 5);
        b.gate(Gate::Min3, &[0, 2, 3], 6);
        b.gate(Gate::Min3, &[1, 2, 3], 7);
        b.gate(Gate::Min3, &[5, 6, 7], 8);
        let prog = b.finish();
        x.run_program(&prog, None).unwrap();
        for r in 0..8 {
            let (a, bb, c) = ((r >> 2) & 1, (r >> 1) & 1, r & 1);
            assert_eq!(x.get(r, 4), a + bb + c >= 2, "cout row {r}");
            assert_eq!(x.get(r, 8), (a + bb + c) % 2 == 1, "sum row {r}");
        }
        assert_eq!(x.stats.cycles, 6);
        assert_eq!(x.stats.gate_instances, 6 * 8);
    }

    #[test]
    fn compiled_plan_reuse_matches_run_program() {
        // Compile once, run twice on two crossbars; identical to two
        // run_program calls, stats included.
        let mut b = RowProgramBuilder::new("reuse");
        b.gate(Gate::Nor2, &[0, 1], 2);
        b.gate(Gate::Min3, &[0, 1, 2], 3);
        let prog = b.finish();
        let init = |x: &mut Crossbar| {
            for r in 0..96 {
                x.state_mut().set(r, 0, r % 2 == 0);
                x.state_mut().set(r, 1, r % 5 == 0);
            }
        };
        let mut xa = Crossbar::new(96, 8);
        init(&mut xa);
        let plan = xa.compile_plan(&prog).unwrap();
        xa.run_plan(&plan, None).unwrap();
        xa.run_plan(&plan, None).unwrap();
        let mut xb = Crossbar::new(96, 8);
        init(&mut xb);
        xb.run_program(&prog, None).unwrap();
        xb.run_program(&prog, None).unwrap();
        assert_eq!(xa.state(), xb.state());
        assert_eq!(xa.stats, xb.stats);
    }

    #[test]
    fn run_plan_rejects_wrong_shape_or_partitions() {
        let mut prog = Program::new("par");
        prog.push_parallel(vec![
            MicroOp::row(Gate::Not, &[0], 1),
            MicroOp::row(Gate::Not, &[4], 5),
        ]);
        let mut x = Crossbar::new(8, 8);
        x.set_col_partitions(Partitions::uniform(8, 4));
        let plan = x.compile_plan(&prog).unwrap();
        // Same shape, different partitions: rejected.
        let mut y = Crossbar::new(8, 8);
        assert!(y.run_plan(&plan, None).is_err());
        y.set_col_partitions(Partitions::uniform(8, 2));
        assert!(y.run_plan(&plan, None).is_err());
        // Matching partitions: accepted.
        let mut z = Crossbar::new(8, 8);
        z.set_col_partitions(Partitions::uniform(8, 4));
        z.run_plan(&plan, None).unwrap();
        // Different shape: rejected.
        let mut w = Crossbar::new(16, 8);
        w.set_col_partitions(Partitions::uniform(8, 4));
        assert!(w.run_plan(&plan, None).is_err());
    }

    #[test]
    fn serial_scheduled_plan_matches_legacy_wear_accounting() {
        // Cycle-accounting parity pin: a schedule that packs nothing (a
        // pure dependency chain) falls back to the serial plan, and even
        // under error injection its execution is bit- and stats-identical
        // to the legacy per-step path. The wear model that
        // `health`/`lifetime` read (cycles, switched_bits) cannot drift
        // through the bundled interpreter.
        let mut b = RowProgramBuilder::no_init("wear");
        b.gate(Gate::Nor2, &[0, 1], 2);
        b.gate(Gate::Not, &[2], 3);
        b.gate(Gate::Min3, &[0, 2, 3], 4);
        let prog = b.finish();
        let init = |x: &mut Crossbar| {
            for r in 0..96 {
                x.state_mut().set(r, 0, r % 3 == 0);
                x.state_mut().set(r, 1, r % 5 == 0);
            }
        };
        let mut xa = Crossbar::new(96, 8);
        init(&mut xa);
        let plan = xa.compile_plan_scheduled(&prog, ScheduleConfig::packed(4)).unwrap();
        assert!(!plan.is_scheduled(), "a RAW chain packs nothing");
        let mut ia = Injector::new(ErrorModel::direct_only(0.05), 99, 0);
        xa.run_plan(&plan, Some(&mut ia)).unwrap();
        let mut xb = Crossbar::new(96, 8);
        init(&mut xb);
        let mut ib = Injector::new(ErrorModel::direct_only(0.05), 99, 0);
        xb.run_program_uncompiled(&prog, Some(&mut ib)).unwrap();
        assert_eq!(xa.state(), xb.state());
        assert_eq!(xa.stats, xb.stats);
        assert_eq!(ia.counters.gate_flips, ib.counters.gate_flips);
    }

    #[test]
    fn scheduled_plan_matches_reference_and_saves_cycles() {
        // Independent gates on disjoint columns: the scheduler packs
        // them. In the clean model the packed execution is bit-identical
        // to the program-order reference — same state, switches, energy —
        // and only the cycle count shrinks (even after paying the
        // partition-reconfiguration cycle).
        let mut b = RowProgramBuilder::no_init("pack");
        b.gate(Gate::Not, &[0], 1);
        b.gate(Gate::Not, &[4], 5);
        b.gate(Gate::Nor2, &[8, 9], 10);
        b.gate(Gate::Nor2, &[1, 5], 2);
        let prog = b.finish();
        let init = |x: &mut Crossbar| {
            for r in 0..64 {
                x.state_mut().set(r, 0, r % 2 == 0);
                x.state_mut().set(r, 4, r % 3 == 0);
                x.state_mut().set(r, 8, r % 5 == 0);
                x.state_mut().set(r, 9, r % 7 == 0);
            }
        };
        let mut xa = Crossbar::new(64, 16);
        init(&mut xa);
        let plan = xa.compile_plan_scheduled(&prog, ScheduleConfig::packed(4)).unwrap();
        assert!(plan.is_scheduled());
        assert_eq!(plan.cycles(), 2, "ops 0..3 pack, op 3 depends on both NOTs");
        if let Some(parts) = plan.required_col_partitions() {
            xa.set_col_partitions(parts.clone());
        }
        xa.run_plan(&plan, None).unwrap();
        let mut xb = Crossbar::new(64, 16);
        init(&mut xb);
        xb.run_program_uncompiled(&prog, None).unwrap();
        assert_eq!(xa.state(), xb.state());
        assert_eq!(xa.stats.switched_bits, xb.stats.switched_bits);
        assert_eq!(xa.stats.logic_ops, xb.stats.logic_ops);
        assert_eq!(xa.stats.gate_instances, xb.stats.gate_instances);
        assert!((xa.stats.energy_pj - xb.stats.energy_pj).abs() < 1e-9);
        assert!(
            xa.stats.cycles < xb.stats.cycles,
            "reconfig + packed cycles ({}) must beat serial ({})",
            xa.stats.cycles,
            xb.stats.cycles
        );
    }

    #[test]
    fn write_bit_counts_cycles_and_switches() {
        let mut x = Crossbar::new(4, 4);
        x.write_bit(1, 1, true, None);
        x.write_bit(1, 1, true, None); // no switch
        assert_eq!(x.stats.cycles, 2);
        assert_eq!(x.stats.switched_bits, 1);
    }

    #[test]
    fn energy_accumulates_with_ops() {
        let mut x = xbar_with_inputs(64, 4, |r, _| r % 2 == 0);
        x.apply_step(&Step::one(MicroOp::row(Gate::Not, &[0], 1)), None).unwrap();
        assert!(x.stats.energy_pj > 0.0);
        assert!(x.stats.switched_bits > 0);
    }

    #[test]
    fn stats_add() {
        let mut a = XbarStats { cycles: 1, logic_ops: 2, ..Default::default() };
        let b = XbarStats { cycles: 3, energy_pj: 1.5, ..Default::default() };
        a.add(&b);
        assert_eq!(a.cycles, 4);
        assert_eq!(a.logic_ops, 2);
        assert!((a.energy_pj - 1.5).abs() < 1e-12);
    }
}
