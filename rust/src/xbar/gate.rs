//! Stateful logic gates (paper §II-A).
//!
//! The mMPU's logic families: MAGIC (NOT / NOR, including 3-input NOR),
//! FELIX (OR, NAND, Minority3), plus IMPLY material implication. SET0/SET1
//! model the output-initialization write cycles that MAGIC/FELIX require
//! before each gate, and NOP pads encoded programs.
//!
//! Gates evaluate on packed 64-bit words: one call computes the gate for
//! 64 crossbar rows at once — the word-level mirror of the crossbar's
//! inherent row parallelism.

/// A stateful logic gate. Opcode values MUST match
/// `python/compile/kernels/ref.py` (the AOT executor's encoding).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Gate {
    Nop = 0,
    Not = 1,
    Nor2 = 2,
    Nor3 = 3,
    Or2 = 4,
    Nand2 = 5,
    Min3 = 6,
    Set1 = 7,
    Set0 = 8,
    /// IMPLY: out' = a -> out  (material implication; reuses the output
    /// memristor as the second operand, as in the IMPLY family).
    /// Not part of the AOT encoding (the executor covers MAGIC/FELIX);
    /// `encode` lowers it away.
    Imply = 9,
}

pub const NUM_ENCODABLE_OPCODES: u8 = 9;

impl Gate {
    /// Number of *input* operands read by the gate.
    pub fn arity(self) -> usize {
        match self {
            Gate::Nop | Gate::Set1 | Gate::Set0 => 0,
            Gate::Not => 1,
            Gate::Nor2 | Gate::Or2 | Gate::Nand2 => 2,
            Gate::Nor3 | Gate::Min3 => 3,
            Gate::Imply => 1, // reads `a` and the current output state
        }
    }

    /// Word-parallel evaluation: `a`,`b`,`c` are 64 rows of each operand,
    /// `out_prev` the current output column word (used by IMPLY/NOP).
    #[inline]
    pub fn eval_word(self, a: u64, b: u64, c: u64, out_prev: u64) -> u64 {
        match self {
            Gate::Nop => out_prev,
            Gate::Not => !a,
            Gate::Nor2 => !(a | b),
            Gate::Nor3 => !(a | b | c),
            Gate::Or2 => a | b,
            Gate::Nand2 => !(a & b),
            Gate::Min3 => !((a & b) | (a & c) | (b & c)),
            Gate::Set1 => u64::MAX,
            Gate::Set0 => 0,
            Gate::Imply => !a | out_prev,
        }
    }

    /// Scalar (single-row) evaluation — used by tests and the slow path.
    #[inline]
    pub fn eval_bit(self, a: bool, b: bool, c: bool, out_prev: bool) -> bool {
        let w = self.eval_word(
            if a { 1 } else { 0 },
            if b { 1 } else { 0 },
            if c { 1 } else { 0 },
            if out_prev { 1 } else { 0 },
        );
        w & 1 == 1
    }

    /// Whether executing this gate counts as a soft-error site for the
    /// `p_gate` direct-error model (SET init writes use `p_write`; NOP is
    /// never a site).
    pub fn is_logic(self) -> bool {
        !matches!(self, Gate::Nop | Gate::Set1 | Gate::Set0)
    }

    pub fn is_init(self) -> bool {
        matches!(self, Gate::Set1 | Gate::Set0)
    }

    /// Opcode for the AOT gate-scan executor.
    pub fn opcode(self) -> u8 {
        debug_assert!(
            !matches!(self, Gate::Imply),
            "IMPLY must be lowered before encoding"
        );
        self as u8
    }

    pub fn from_opcode(op: u8) -> Option<Gate> {
        Some(match op {
            0 => Gate::Nop,
            1 => Gate::Not,
            2 => Gate::Nor2,
            3 => Gate::Nor3,
            4 => Gate::Or2,
            5 => Gate::Nand2,
            6 => Gate::Min3,
            7 => Gate::Set1,
            8 => Gate::Set0,
            9 => Gate::Imply,
            _ => return None,
        })
    }

    pub const ALL: [Gate; 10] = [
        Gate::Nop,
        Gate::Not,
        Gate::Nor2,
        Gate::Nor3,
        Gate::Or2,
        Gate::Nand2,
        Gate::Min3,
        Gate::Set1,
        Gate::Set0,
        Gate::Imply,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truth_tables() {
        for a in [false, true] {
            for b in [false, true] {
                for c in [false, true] {
                    assert_eq!(Gate::Not.eval_bit(a, b, c, false), !a);
                    assert_eq!(Gate::Nor2.eval_bit(a, b, c, false), !(a | b));
                    assert_eq!(Gate::Nor3.eval_bit(a, b, c, false), !(a | b | c));
                    assert_eq!(Gate::Or2.eval_bit(a, b, c, false), a | b);
                    assert_eq!(Gate::Nand2.eval_bit(a, b, c, false), !(a & b));
                    let maj = (a & b) | (a & c) | (b & c);
                    assert_eq!(Gate::Min3.eval_bit(a, b, c, false), !maj);
                    assert!(Gate::Set1.eval_bit(a, b, c, false));
                    assert!(!Gate::Set0.eval_bit(a, b, c, false));
                    for prev in [false, true] {
                        assert_eq!(Gate::Nop.eval_bit(a, b, c, prev), prev);
                        assert_eq!(Gate::Imply.eval_bit(a, b, c, prev), !a | prev);
                    }
                }
            }
        }
    }

    #[test]
    fn word_matches_bits() {
        // Words evaluate 64 independent rows: check against per-bit eval.
        let a = 0xDEAD_BEEF_0123_4567u64;
        let b = 0xFEED_FACE_89AB_CDEFu64;
        let c = 0x0F0F_F0F0_AA55_55AAu64;
        let p = 0x1234_5678_9ABC_DEF0u64;
        for g in Gate::ALL {
            let w = g.eval_word(a, b, c, p);
            for i in 0..64 {
                let bit = |x: u64| (x >> i) & 1 == 1;
                assert_eq!(bit(w), g.eval_bit(bit(a), bit(b), bit(c), bit(p)), "{g:?} bit {i}");
            }
        }
    }

    #[test]
    fn opcode_roundtrip() {
        for g in Gate::ALL {
            if g != Gate::Imply {
                assert_eq!(Gate::from_opcode(g.opcode()), Some(g));
            }
        }
        assert_eq!(Gate::from_opcode(42), None);
    }

    #[test]
    fn error_site_classification() {
        assert!(Gate::Nor2.is_logic() && Gate::Min3.is_logic() && Gate::Imply.is_logic());
        assert!(!Gate::Set1.is_logic() && !Gate::Nop.is_logic());
        assert!(Gate::Set0.is_init() && !Gate::Nor2.is_init());
    }
}
