//! Durable flight recorder: an append-only write-ahead log that
//! spills the in-memory [`EventJournal`] ring to disk, so a crashed
//! shard's reliability story survives the process (ROADMAP
//! §Telemetry carryover: "events die with the process today").
//!
//! The WAL is **forensic, not state**: a rebooting process never
//! replays it. On boot it mints a random non-zero `boot_epoch`,
//! opens a fresh segment stamped with that epoch, and a background
//! flusher thread drains the journal ring through its ordinary
//! `since(cursor)` API — event *emission* stays exactly as lock-free
//! as before; only the flusher ever touches the filesystem.
//!
//! On-disk format (all integers little-endian):
//!
//! ```text
//! segment file  wal-<epoch:016x>-<index:08>.seg
//!   header      "REMUSWAL" magic ‖ u32 format version ‖ u64 boot_epoch
//!   record*     u32 len ‖ u32 crc32(payload) ‖ payload
//!   payload     u64 seq ‖ u32 shard ‖ u64 at_ns ‖ u8 tag ‖ u64 a ‖ u64 b ‖ u64 c
//! ```
//!
//! A torn or bit-flipped tail record fails its length bound or CRC
//! and cleanly ends the segment read — everything before the damage
//! is recovered verbatim (property-tested in
//! `tests/prop_telemetry.rs`). A CRC-valid record whose event tag is
//! unknown (written by a newer build) is skipped, not fatal.
//! Segments rotate at a size threshold and the writer deletes the
//! oldest closed segments to keep the directory under a total
//! footprint bound.

use std::fs;
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::journal::{unix_now_ns, Event, EventJournal, EventKind};
use super::splitmix64;

/// Segment header magic.
pub const WAL_MAGIC: [u8; 8] = *b"REMUSWAL";
/// On-disk format version (bumped only on incompatible layout change).
pub const WAL_FORMAT: u32 = 1;
/// Header size: magic + format + boot_epoch.
pub const WAL_HEADER_LEN: usize = 8 + 4 + 8;
/// Fixed payload size of one event record (see module docs).
pub const WAL_RECORD_LEN: usize = 8 + 4 + 8 + 1 + 8 + 8 + 8;
/// Upper bound a record length prefix may claim before the reader
/// declares the tail torn (guards against reading garbage lengths).
pub const WAL_MAX_RECORD: u32 = 4096;

const CRC_TABLE: [u32; 256] = crc32_table();

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial), hand-rolled — the
/// offline vendor set has no checksum crate.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Mint a random non-zero boot epoch: splitmix64 over the boot
/// clock, pid, and a process-local counter (no rand crate in the
/// vendor set; uniqueness across restarts of the same process image
/// is what matters, not unpredictability).
pub fn mint_boot_epoch() -> u64 {
    static SALT: AtomicU64 = AtomicU64::new(0);
    let salt = SALT.fetch_add(1, Ordering::Relaxed);
    let mut x = unix_now_ns() ^ ((std::process::id() as u64) << 32) ^ (salt << 17);
    loop {
        x = splitmix64(x.wrapping_add(0x9E37_79B9));
        if x != 0 {
            return x;
        }
    }
}

/// Durability mode for WAL appends. The loadgen
/// `journal_persistence_overhead` row measures all three arms (off /
/// buffered / per-batch fsync) so the durability-vs-latency trade is
/// a recorded number, not a guess.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncMode {
    /// OS-buffered writes, flushed to the file per batch; survives
    /// process crashes (the forensic case) but not power loss.
    Buffered,
    /// `fsync` after every appended batch; survives power loss at a
    /// per-batch syscall cost.
    PerBatch,
}

/// WAL tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct WalConfig {
    /// Rotate to a new segment once the current one exceeds this.
    pub segment_bytes: u64,
    /// Delete oldest closed segments to keep the directory under
    /// this total footprint.
    pub max_total_bytes: u64,
    pub fsync: FsyncMode,
    /// How often the flusher thread drains the journal ring.
    pub flush_interval: Duration,
}

impl Default for WalConfig {
    fn default() -> Self {
        Self {
            segment_bytes: 256 * 1024,
            max_total_bytes: 4 * 1024 * 1024,
            fsync: FsyncMode::Buffered,
            flush_interval: Duration::from_millis(50),
        }
    }
}

fn segment_path(dir: &Path, epoch: u64, index: u32) -> PathBuf {
    dir.join(format!("wal-{epoch:016x}-{index:08}.seg"))
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Encode one event's record payload (without the len/crc framing).
fn encode_payload(e: &Event) -> Vec<u8> {
    let (tag, a, b, c) = e.kind.to_words();
    let mut out = Vec::with_capacity(WAL_RECORD_LEN);
    put_u64(&mut out, e.seq);
    put_u32(&mut out, e.shard);
    put_u64(&mut out, e.at_ns);
    out.push(tag);
    put_u64(&mut out, a);
    put_u64(&mut out, b);
    put_u64(&mut out, c);
    out
}

/// Decode a record payload; `None` when the length is wrong or the
/// event tag is unknown (a newer writer's kind — skippable).
fn decode_payload(p: &[u8]) -> Option<Event> {
    if p.len() != WAL_RECORD_LEN {
        return None;
    }
    let u64_at = |i: usize| u64::from_le_bytes(p[i..i + 8].try_into().expect("8 bytes"));
    let seq = u64_at(0);
    let shard = u32::from_le_bytes(p[8..12].try_into().expect("4 bytes"));
    let at_ns = u64_at(12);
    let tag = p[20];
    let kind = EventKind::from_words(tag, u64_at(21), u64_at(29), u64_at(37))?;
    Some(Event { seq, shard, at_ns, kind })
}

/// Append-only segment writer for one process lifetime (one epoch).
pub struct WalWriter {
    dir: PathBuf,
    epoch: u64,
    cfg: WalConfig,
    file: fs::File,
    seg_index: u32,
    seg_bytes: u64,
}

impl WalWriter {
    /// Create the directory if needed and open a fresh segment
    /// stamped with `epoch`. Nothing is replayed: the WAL is
    /// forensic output only.
    pub fn create(dir: &Path, epoch: u64, cfg: WalConfig) -> io::Result<WalWriter> {
        fs::create_dir_all(dir)?;
        let mut w = WalWriter {
            dir: dir.to_path_buf(),
            epoch,
            cfg,
            file: Self::open_segment(dir, epoch, 0)?,
            seg_index: 0,
            seg_bytes: WAL_HEADER_LEN as u64,
        };
        w.enforce_footprint()?;
        Ok(w)
    }

    fn open_segment(dir: &Path, epoch: u64, index: u32) -> io::Result<fs::File> {
        let mut file = fs::File::create(segment_path(dir, epoch, index))?;
        let mut header = Vec::with_capacity(WAL_HEADER_LEN);
        header.extend_from_slice(&WAL_MAGIC);
        put_u32(&mut header, WAL_FORMAT);
        put_u64(&mut header, epoch);
        file.write_all(&header)?;
        Ok(file)
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Append a batch of events as checksummed records, flush once,
    /// fsync if configured, then rotate/garbage-collect if the
    /// segment grew past its threshold.
    pub fn append_batch(&mut self, events: &[Event]) -> io::Result<()> {
        if events.is_empty() {
            return Ok(());
        }
        let mut buf = Vec::with_capacity(events.len() * (WAL_RECORD_LEN + 8));
        for e in events {
            let payload = encode_payload(e);
            put_u32(&mut buf, payload.len() as u32);
            put_u32(&mut buf, crc32(&payload));
            buf.extend_from_slice(&payload);
        }
        self.file.write_all(&buf)?;
        self.file.flush()?;
        if self.cfg.fsync == FsyncMode::PerBatch {
            self.file.sync_data()?;
        }
        self.seg_bytes += buf.len() as u64;
        if self.seg_bytes >= self.cfg.segment_bytes {
            self.rotate()?;
        }
        Ok(())
    }

    fn rotate(&mut self) -> io::Result<()> {
        self.file.flush()?;
        if self.cfg.fsync == FsyncMode::PerBatch {
            self.file.sync_data()?;
        }
        self.seg_index += 1;
        self.file = Self::open_segment(&self.dir, self.epoch, self.seg_index)?;
        self.seg_bytes = WAL_HEADER_LEN as u64;
        self.enforce_footprint()
    }

    /// Delete the oldest *closed* segments (never the active one)
    /// until the directory's total WAL footprint fits the bound.
    fn enforce_footprint(&self) -> io::Result<()> {
        let active = segment_path(&self.dir, self.epoch, self.seg_index);
        let mut segs: Vec<(std::time::SystemTime, PathBuf, u64)> = Vec::new();
        let mut total = 0u64;
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let path = entry.path();
            if !is_segment_name(&path) {
                continue;
            }
            let meta = entry.metadata()?;
            total += meta.len();
            if path != active {
                let mtime = meta.modified().unwrap_or(std::time::UNIX_EPOCH);
                segs.push((mtime, path, meta.len()));
            }
        }
        segs.sort();
        for (_, path, len) in segs {
            if total <= self.cfg.max_total_bytes {
                break;
            }
            fs::remove_file(&path)?;
            total -= len;
        }
        Ok(())
    }
}

fn is_segment_name(path: &Path) -> bool {
    path.file_name()
        .and_then(|n| n.to_str())
        .map(|n| n.starts_with("wal-") && n.ends_with(".seg"))
        .unwrap_or(false)
}

/// One segment, read back: its stamped epoch and every record
/// recovered before the first torn/corrupt one.
#[derive(Clone, Debug)]
pub struct SegmentRead {
    pub epoch: u64,
    pub events: Vec<Event>,
    /// True when the read ended at a damaged record rather than a
    /// clean EOF — the expected state of a SIGKILLed writer's last
    /// segment, worth surfacing in a post-mortem report.
    pub torn_tail: bool,
}

/// Read one segment file. Bad magic / header is an error (not a WAL
/// segment at all); a damaged record merely ends the read, keeping
/// every record before it.
pub fn read_segment(path: &Path) -> io::Result<SegmentRead> {
    let mut data = Vec::new();
    fs::File::open(path)?.read_to_end(&mut data)?;
    if data.len() < WAL_HEADER_LEN || data[..8] != WAL_MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "not a WAL segment"));
    }
    let format = u32::from_le_bytes(data[8..12].try_into().expect("4 bytes"));
    if format != WAL_FORMAT {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unsupported WAL format {format}"),
        ));
    }
    let epoch = u64::from_le_bytes(data[12..20].try_into().expect("8 bytes"));
    let mut events = Vec::new();
    let mut torn_tail = false;
    let mut at = WAL_HEADER_LEN;
    while at < data.len() {
        if data.len() - at < 8 {
            torn_tail = true;
            break;
        }
        let len = u32::from_le_bytes(data[at..at + 4].try_into().expect("4 bytes"));
        let crc = u32::from_le_bytes(data[at + 4..at + 8].try_into().expect("4 bytes"));
        if len > WAL_MAX_RECORD || data.len() - at - 8 < len as usize {
            torn_tail = true;
            break;
        }
        let payload = &data[at + 8..at + 8 + len as usize];
        if crc32(payload) != crc {
            torn_tail = true;
            break;
        }
        at += 8 + len as usize;
        // CRC-valid but undecodable = a newer writer's kind: skip the
        // record, keep reading — unlike damage, the framing is intact.
        if let Some(e) = decode_payload(payload) {
            events.push(e);
        }
    }
    Ok(SegmentRead { epoch, events, torn_tail })
}

/// One process lifetime reconstructed from a WAL directory: all
/// recovered events of one boot epoch, in append order.
#[derive(Clone, Debug)]
pub struct EpochTimeline {
    pub epoch: u64,
    pub events: Vec<Event>,
    pub segments: usize,
    pub torn_tail: bool,
}

/// Read every segment in `dir`, grouped per boot epoch, epochs
/// ordered by their first recovered timestamp (wall clock — the
/// epochs themselves are random). Non-segment files are ignored;
/// unreadable segments are skipped rather than failing the whole
/// post-mortem (the directory may hold a live writer's file).
pub fn read_wal_dir(dir: &Path) -> io::Result<Vec<EpochTimeline>> {
    let mut paths: Vec<PathBuf> = fs::read_dir(dir)?
        .flatten()
        .map(|e| e.path())
        .filter(|p| is_segment_name(p))
        .collect();
    // Name order = segment index order within an epoch (zero-padded).
    paths.sort();
    let mut timelines: Vec<EpochTimeline> = Vec::new();
    for path in paths {
        let Ok(seg) = read_segment(&path) else { continue };
        match timelines.iter_mut().find(|t| t.epoch == seg.epoch) {
            Some(t) => {
                t.events.extend(seg.events);
                t.segments += 1;
                t.torn_tail |= seg.torn_tail;
            }
            None => timelines.push(EpochTimeline {
                epoch: seg.epoch,
                events: seg.events,
                segments: 1,
                torn_tail: seg.torn_tail,
            }),
        }
    }
    timelines.sort_by_key(|t| t.events.first().map(|e| e.at_ns).unwrap_or(u64::MAX));
    Ok(timelines)
}

/// Background flusher: drains the journal ring through its ordinary
/// cursor API into a [`WalWriter`], so event emission never sees the
/// filesystem. Dropped batches are impossible below ring capacity;
/// past it the ring's own newest-wins policy applies (same contract
/// as every other journal reader).
pub struct WalFlusher {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl WalFlusher {
    /// Open the WAL in `dir` under `epoch` and start the flusher
    /// thread.
    pub fn spawn(
        journal: Arc<EventJournal>,
        dir: &Path,
        epoch: u64,
        cfg: WalConfig,
    ) -> io::Result<WalFlusher> {
        let mut writer = WalWriter::create(dir, epoch, cfg)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("wal-flusher".into())
            .spawn(move || {
                let mut cursor = 0u64;
                loop {
                    let stopping = stop2.load(Ordering::Acquire);
                    let (events, latest) = journal.since(cursor);
                    cursor = latest;
                    if writer.append_batch(&events).is_err() {
                        // Disk trouble must never take down serving:
                        // the WAL is forensic. Stop flushing; the
                        // in-memory journal keeps working.
                        return;
                    }
                    if stopping {
                        return;
                    }
                    std::thread::park_timeout(cfg.flush_interval);
                }
            })
            .expect("spawn wal-flusher");
        Ok(WalFlusher { stop, handle: Some(handle) })
    }

    /// Signal the flusher, let it run one final drain, and join.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            h.thread().unpark();
            let _ = h.join();
        }
    }
}

impl Drop for WalFlusher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_check_vector() {
        // The canonical CRC-32/IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn boot_epochs_are_nonzero_and_distinct() {
        let a = mint_boot_epoch();
        let b = mint_boot_epoch();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b, "two mints in one process must differ");
    }

    #[test]
    fn writer_reader_roundtrip_and_torn_tail_is_clean() {
        let dir = std::env::temp_dir().join(format!("remus-wal-test-{}", mint_boot_epoch()));
        let epoch = 0x1234_5678_9ABC_DEF0u64;
        let events: Vec<Event> = (0..10)
            .map(|i| Event {
                seq: i,
                shard: 0,
                at_ns: 1000 + i,
                kind: EventKind::StuckCell { worker: i as u32, cells: i * 3 },
            })
            .collect();
        let mut w = WalWriter::create(&dir, epoch, WalConfig::default()).unwrap();
        w.append_batch(&events).unwrap();
        drop(w);
        let path = segment_path(&dir, epoch, 0);
        let seg = read_segment(&path).unwrap();
        assert_eq!(seg.epoch, epoch);
        assert_eq!(seg.events, events);
        assert!(!seg.torn_tail);
        // Truncate mid-record: everything before the cut survives.
        let data = fs::read(&path).unwrap();
        fs::write(&path, &data[..data.len() - 3]).unwrap();
        let seg = read_segment(&path).unwrap();
        assert_eq!(seg.events, events[..events.len() - 1]);
        assert!(seg.torn_tail);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn flusher_drains_the_journal_to_disk() {
        let dir = std::env::temp_dir().join(format!("remus-wal-test-{}", mint_boot_epoch()));
        let journal = Arc::new(EventJournal::new(64));
        let epoch = mint_boot_epoch();
        let cfg = WalConfig { flush_interval: Duration::from_millis(5), ..Default::default() };
        let flusher = WalFlusher::spawn(Arc::clone(&journal), &dir, epoch, cfg).unwrap();
        for i in 0..5 {
            journal.record(EventKind::RowRemap { worker: i, rows: 2 });
        }
        flusher.stop();
        let timelines = read_wal_dir(&dir).unwrap();
        assert_eq!(timelines.len(), 1);
        assert_eq!(timelines[0].epoch, epoch);
        assert_eq!(timelines[0].events.len(), 5);
        assert!(!timelines[0].torn_tail);
        fs::remove_dir_all(&dir).unwrap();
    }
}
