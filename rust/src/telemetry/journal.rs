//! The reliability event journal: a bounded ring of structured
//! events with monotonic sequence numbers, recording every
//! reliability-relevant transition the stack makes — scrub results,
//! stuck-cell detections, row remaps, policy moves, worker
//! retirement and spare promotion, shard membership changes,
//! heartbeat timeouts, failover replays, auth rejects.
//!
//! Each process keeps its own journal; the router pulls shard
//! journals over the control plane (`Events{since}` with a per-shard
//! cursor) and merges them with its own into one fleet-wide,
//! causally ordered view. Timestamps are unix-epoch nanoseconds so
//! events from different processes sort into one timeline.

use std::time::{SystemTime, UNIX_EPOCH};

use super::ring::SlotRing;

/// Default journal capacity (most recent events kept).
pub const DEFAULT_JOURNAL_CAPACITY: usize = 1024;

/// `Event.shard` value for events that are about the fleet fabric
/// itself rather than any one shard (e.g. an auth reject observed at
/// the router's front door).
pub const SHARD_NONE: u32 = u32::MAX;

/// A structured reliability event. `worker` fields are worker/unit
/// indices within the recording shard; `shard` fields are fleet
/// shard slots. Counters are clamped to u32 on the wire where packed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A scrub pass completed on `worker` with these totals.
    Scrub { worker: u32, corrected: u64, detected: u32, remapped: u32 },
    /// Scrub found `cells` newly stuck cells on `worker`.
    StuckCell { worker: u32, cells: u64 },
    /// `rows` faulty rows were remapped to spares on `worker`.
    RowRemap { worker: u32, rows: u64 },
    /// Reliability policy for `worker` escalated to `level`.
    PolicyEscalate { worker: u32, level: u8 },
    /// Reliability policy for `worker` relaxed to `level`.
    PolicyDeescalate { worker: u32, level: u8 },
    /// `worker` was retired from serving (spares exhausted or worn).
    WorkerRetire { worker: u32 },
    /// A spare unit was promoted into serving slot `unit`.
    SparePromote { unit: u32 },
    /// Serving unit `unit` was demoted back to the spare pool.
    SpareDemote { unit: u32 },
    /// Shard `shard` was marked down.
    ShardDown { shard: u32 },
    /// Shard `shard` revived and rejoined the ring.
    ShardRevive { shard: u32 },
    /// Shard `shard` missed its heartbeat deadline.
    HeartbeatTimeout { shard: u32 },
    /// `replayed` in-flight requests were re-routed after shard
    /// `shard` failed.
    FailoverReplay { shard: u32, replayed: u64 },
    /// A peer failed authentication (handshake or sealed-frame
    /// integrity) and was rejected.
    AuthReject,
    /// Shard `shard` came back with a new `boot_epoch` (process
    /// restart): its journal restarted at seq 0, so the router reset
    /// its cursor. Synthesized by the router, never by a shard.
    ShardRestarted { shard: u32, epoch: u64 },
}

impl EventKind {
    /// Stable wire tag. Unknown tags on decode are a clean error,
    /// never a panic.
    pub fn tag(&self) -> u8 {
        match self {
            EventKind::Scrub { .. } => 1,
            EventKind::StuckCell { .. } => 2,
            EventKind::RowRemap { .. } => 3,
            EventKind::PolicyEscalate { .. } => 4,
            EventKind::PolicyDeescalate { .. } => 5,
            EventKind::WorkerRetire { .. } => 6,
            EventKind::SparePromote { .. } => 7,
            EventKind::SpareDemote { .. } => 8,
            EventKind::ShardDown { .. } => 9,
            EventKind::ShardRevive { .. } => 10,
            EventKind::HeartbeatTimeout { .. } => 11,
            EventKind::FailoverReplay { .. } => 12,
            EventKind::AuthReject => 13,
            EventKind::ShardRestarted { .. } => 14,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Scrub { .. } => "scrub",
            EventKind::StuckCell { .. } => "stuck_cell",
            EventKind::RowRemap { .. } => "row_remap",
            EventKind::PolicyEscalate { .. } => "policy_escalate",
            EventKind::PolicyDeescalate { .. } => "policy_deescalate",
            EventKind::WorkerRetire { .. } => "worker_retire",
            EventKind::SparePromote { .. } => "spare_promote",
            EventKind::SpareDemote { .. } => "spare_demote",
            EventKind::ShardDown { .. } => "shard_down",
            EventKind::ShardRevive { .. } => "shard_revive",
            EventKind::HeartbeatTimeout { .. } => "heartbeat_timeout",
            EventKind::FailoverReplay { .. } => "failover_replay",
            EventKind::AuthReject => "auth_reject",
            EventKind::ShardRestarted { .. } => "shard_restarted",
        }
    }

    /// Pack into `(tag, a, b, c)` payload words for the slot ring and
    /// the wire. Inverse of [`EventKind::from_words`].
    pub fn to_words(&self) -> (u8, u64, u64, u64) {
        match *self {
            EventKind::Scrub { worker, corrected, detected, remapped } => {
                (1, worker as u64, corrected, ((detected as u64) << 32) | remapped as u64)
            }
            EventKind::StuckCell { worker, cells } => (2, worker as u64, cells, 0),
            EventKind::RowRemap { worker, rows } => (3, worker as u64, rows, 0),
            EventKind::PolicyEscalate { worker, level } => (4, worker as u64, level as u64, 0),
            EventKind::PolicyDeescalate { worker, level } => (5, worker as u64, level as u64, 0),
            EventKind::WorkerRetire { worker } => (6, worker as u64, 0, 0),
            EventKind::SparePromote { unit } => (7, unit as u64, 0, 0),
            EventKind::SpareDemote { unit } => (8, unit as u64, 0, 0),
            EventKind::ShardDown { shard } => (9, shard as u64, 0, 0),
            EventKind::ShardRevive { shard } => (10, shard as u64, 0, 0),
            EventKind::HeartbeatTimeout { shard } => (11, shard as u64, 0, 0),
            EventKind::FailoverReplay { shard, replayed } => (12, shard as u64, replayed, 0),
            EventKind::AuthReject => (13, 0, 0, 0),
            EventKind::ShardRestarted { shard, epoch } => (14, shard as u64, epoch, 0),
        }
    }

    /// Decode from payload words; `None` for an unknown tag.
    pub fn from_words(tag: u8, a: u64, b: u64, c: u64) -> Option<EventKind> {
        Some(match tag {
            1 => EventKind::Scrub {
                worker: a as u32,
                corrected: b,
                detected: (c >> 32) as u32,
                remapped: c as u32,
            },
            2 => EventKind::StuckCell { worker: a as u32, cells: b },
            3 => EventKind::RowRemap { worker: a as u32, rows: b },
            4 => EventKind::PolicyEscalate { worker: a as u32, level: b as u8 },
            5 => EventKind::PolicyDeescalate { worker: a as u32, level: b as u8 },
            6 => EventKind::WorkerRetire { worker: a as u32 },
            7 => EventKind::SparePromote { unit: a as u32 },
            8 => EventKind::SpareDemote { unit: a as u32 },
            9 => EventKind::ShardDown { shard: a as u32 },
            10 => EventKind::ShardRevive { shard: a as u32 },
            11 => EventKind::HeartbeatTimeout { shard: a as u32 },
            12 => EventKind::FailoverReplay { shard: a as u32, replayed: b },
            13 => EventKind::AuthReject,
            14 => EventKind::ShardRestarted { shard: a as u32, epoch: b },
            _ => return None,
        })
    }

    /// Human-readable one-liner for `remus top`.
    pub fn describe(&self) -> String {
        match *self {
            EventKind::Scrub { worker, corrected, detected, remapped } => format!(
                "scrub w{worker}: corrected={corrected} detected={detected} remapped={remapped}"
            ),
            EventKind::StuckCell { worker, cells } => {
                format!("stuck cells w{worker}: {cells} new")
            }
            EventKind::RowRemap { worker, rows } => format!("row remap w{worker}: {rows} rows"),
            EventKind::PolicyEscalate { worker, level } => {
                format!("policy escalate w{worker} -> level {level}")
            }
            EventKind::PolicyDeescalate { worker, level } => {
                format!("policy de-escalate w{worker} -> level {level}")
            }
            EventKind::WorkerRetire { worker } => format!("worker retire w{worker}"),
            EventKind::SparePromote { unit } => format!("spare promote -> slot {unit}"),
            EventKind::SpareDemote { unit } => format!("spare demote slot {unit}"),
            EventKind::ShardDown { shard } => format!("shard {shard} DOWN"),
            EventKind::ShardRevive { shard } => format!("shard {shard} revived"),
            EventKind::HeartbeatTimeout { shard } => format!("shard {shard} heartbeat timeout"),
            EventKind::FailoverReplay { shard, replayed } => {
                format!("failover replay from shard {shard}: {replayed} in-flight")
            }
            EventKind::AuthReject => "auth reject".to_string(),
            EventKind::ShardRestarted { shard, epoch } => {
                format!("shard {shard} RESTARTED (boot epoch {epoch:#x}, cursor reset)")
            }
        }
    }
}

/// One journal entry: the kind plus where (shard slot) and when
/// (unix ns) it happened, under a journal-local monotonic `seq`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub seq: u64,
    /// Fleet shard slot the event is about ([`SHARD_NONE`] when the
    /// event is about the fabric itself). A shard-local journal
    /// records its own events with `shard == 0`; the router stamps
    /// the true slot when it imports them.
    pub shard: u32,
    /// Unix-epoch nanoseconds at record time: comparable across
    /// processes, which is what makes the fleet-merged timeline
    /// causally ordered.
    pub at_ns: u64,
    pub kind: EventKind,
}

/// Unix-epoch nanoseconds now (0 if the clock is before the epoch).
pub fn unix_now_ns() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_nanos() as u64).unwrap_or(0)
}

/// Bounded multi-producer journal of [`Event`]s.
///
/// Slot layout: `[shard<<8 | tag, at_ns, a, b, c]`.
pub struct EventJournal {
    ring: SlotRing<5>,
}

impl EventJournal {
    pub fn new(capacity: usize) -> Self {
        Self { ring: SlotRing::new(capacity) }
    }

    /// Record an event about this process (shard slot 0 — the
    /// recorder's own identity; the router re-stamps on import).
    pub fn record(&self, kind: EventKind) -> u64 {
        self.record_for(0, kind)
    }

    /// Record an event attributed to fleet shard slot `shard`.
    pub fn record_for(&self, shard: u32, kind: EventKind) -> u64 {
        let (tag, a, b, c) = kind.to_words();
        self.ring.push([((shard as u64) << 8) | tag as u64, unix_now_ns(), a, b, c])
    }

    /// The next sequence number (== total events ever recorded).
    pub fn next_seq(&self) -> u64 {
        self.ring.pushed()
    }

    pub fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    /// All retained events, oldest first by sequence number.
    pub fn events(&self) -> Vec<Event> {
        self.ring
            .snapshot()
            .into_iter()
            .filter_map(|(seq, [shard_tag, at_ns, a, b, c])| {
                let kind = EventKind::from_words(shard_tag as u8, a, b, c)?;
                Some(Event { seq, shard: (shard_tag >> 8) as u32, at_ns, kind })
            })
            .collect()
    }

    /// Events with `seq >= cursor`, plus the cursor to resume from
    /// (`next_seq`). The cursor always advances past ring-overwritten
    /// gaps: a reader that falls more than `capacity` behind misses
    /// the overwritten middle but never stalls.
    pub fn since(&self, cursor: u64) -> (Vec<Event>, u64) {
        let latest = self.next_seq();
        let mut evs = self.events();
        evs.retain(|e| e.seq >= cursor);
        (evs, latest)
    }
}

/// Total order for the fleet-merged view: wall clock first, then
/// shard, then per-journal sequence, then payload as a tiebreak so
/// the order is total (merge associativity depends on it).
fn total_key(e: &Event) -> (u64, u32, u64, u8, u64, u64, u64) {
    let (tag, a, b, c) = e.kind.to_words();
    (e.at_ns, e.shard, e.seq, tag, a, b, c)
}

/// Merge two event sets into one causally ordered, deduplicated
/// timeline. Pure, associative, and idempotent: re-importing events
/// a cursor already delivered cannot duplicate them.
pub fn merge_events(a: Vec<Event>, b: Vec<Event>) -> Vec<Event> {
    let mut out = a;
    out.extend(b);
    out.sort_unstable_by_key(total_key);
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_roundtrip_through_words() {
        let kinds = [
            EventKind::Scrub { worker: 3, corrected: 99, detected: 7, remapped: 2 },
            EventKind::StuckCell { worker: 1, cells: 12 },
            EventKind::RowRemap { worker: 0, rows: 4 },
            EventKind::PolicyEscalate { worker: 2, level: 2 },
            EventKind::PolicyDeescalate { worker: 2, level: 1 },
            EventKind::WorkerRetire { worker: 5 },
            EventKind::SparePromote { unit: 5 },
            EventKind::SpareDemote { unit: 6 },
            EventKind::ShardDown { shard: 1 },
            EventKind::ShardRevive { shard: 1 },
            EventKind::HeartbeatTimeout { shard: 0 },
            EventKind::FailoverReplay { shard: 1, replayed: 17 },
            EventKind::AuthReject,
            EventKind::ShardRestarted { shard: 1, epoch: 0xDEAD_BEEF },
        ];
        for k in kinds {
            let (tag, a, b, c) = k.to_words();
            assert_eq!(tag, k.tag());
            assert_eq!(EventKind::from_words(tag, a, b, c), Some(k), "roundtrip {}", k.name());
        }
        assert_eq!(EventKind::from_words(0, 0, 0, 0), None);
        assert_eq!(EventKind::from_words(99, 1, 2, 3), None);
    }

    #[test]
    fn since_returns_exactly_the_gap_and_advances() {
        let j = EventJournal::new(64);
        for i in 0..10 {
            j.record(EventKind::ShardDown { shard: i });
        }
        let (all, latest) = j.since(0);
        assert_eq!(all.len(), 10);
        assert_eq!(latest, 10);
        let (gap, latest2) = j.since(7);
        assert_eq!(gap.len(), 3);
        assert_eq!(gap[0].seq, 7);
        assert_eq!(latest2, 10);
        let (none, _) = j.since(latest2);
        assert!(none.is_empty());
    }

    #[test]
    fn merge_orders_by_wall_clock_and_dedups() {
        let mk = |seq, shard, at_ns| Event {
            seq,
            shard,
            at_ns,
            kind: EventKind::ShardDown { shard },
        };
        let a = vec![mk(0, 0, 50), mk(1, 0, 150)];
        let b = vec![mk(0, 1, 100), mk(0, 0, 50)];
        let m = merge_events(a.clone(), b.clone());
        assert_eq!(m.len(), 3, "duplicate (shard 0, seq 0) collapses");
        assert_eq!(m[0].at_ns, 50);
        assert_eq!(m[1].at_ns, 100);
        assert_eq!(m[2].at_ns, 150);
        assert_eq!(merge_events(m.clone(), b), m, "idempotent");
    }
}
