//! Per-request trace spans: a u64 trace id is minted at the
//! submitter, carried end-to-end on the wire, and — for the
//! deterministic 1-in-N sampled subset — each stage of the request
//! path records a `(trace, stage, start, duration)` span into a
//! fixed-capacity [`SlotRing`]. The disabled path (`sample == 0`)
//! is a single branch in [`Tracer::sampled`]; no allocation, no
//! atomic traffic, no clock read.
//!
//! Sampling is keyed off the trace id itself (`splitmix64(trace) %
//! sample == 0`), so every hop of the fleet makes the *same*
//! keep/drop decision for a given request without coordination —
//! the router and each shard record complementary stages of one
//! timeline as long as they agree on the sampling rate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::ring::SlotRing;
use super::splitmix64;

/// Default span-ring capacity (most recent sampled spans kept).
pub const DEFAULT_SPAN_CAPACITY: usize = 4096;

/// The stages of the request path, in causal order. Stages are
/// *disjoint* slices of a request's end-to-end latency (worker exec
/// is the marshalling remainder after ECC / TMR / readback are
/// carved out), so a request's stage durations sum to at most its
/// end-to-end latency.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Submit accepted by the router until its frame hit the socket.
    RouterQueue = 0,
    /// On the wire + shard connection handling: router-observed
    /// round trip minus the shard-reported service time.
    WireTransit = 1,
    /// Queued in the coordinator batcher awaiting dispatch.
    BatcherWait = 2,
    /// Worker execution outside the reliability stages: operand
    /// marshalling, fault scatter, plan interpretation overhead.
    WorkerExec = 3,
    /// ECC codeword verify/correct passes around the computation.
    EccVerify = 4,
    /// The (possibly TMR-replicated) in-crossbar computation itself.
    TmrVote = 5,
    /// Result gather + remapped-row readback overrides.
    Readback = 6,
}

impl Stage {
    /// Every stage, in causal order.
    pub const ALL: [Stage; 7] = [
        Stage::RouterQueue,
        Stage::WireTransit,
        Stage::BatcherWait,
        Stage::WorkerExec,
        Stage::EccVerify,
        Stage::TmrVote,
        Stage::Readback,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::RouterQueue => "router_queue",
            Stage::WireTransit => "wire_transit",
            Stage::BatcherWait => "batcher_wait",
            Stage::WorkerExec => "worker_exec",
            Stage::EccVerify => "ecc_verify",
            Stage::TmrVote => "tmr_vote",
            Stage::Readback => "readback",
        }
    }

    pub fn from_u8(v: u8) -> Option<Stage> {
        Some(match v {
            0 => Stage::RouterQueue,
            1 => Stage::WireTransit,
            2 => Stage::BatcherWait,
            3 => Stage::WorkerExec,
            4 => Stage::EccVerify,
            5 => Stage::TmrVote,
            6 => Stage::Readback,
            _ => return None,
        })
    }
}

/// One recorded stage span of a sampled request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceSpan {
    /// The request's trace id (never 0 for a recorded span).
    pub trace: u64,
    pub stage: Stage,
    /// Start offset in ns since the recording tracer's epoch. Only
    /// comparable between spans recorded by the *same* tracer;
    /// durations are comparable everywhere.
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// Mints trace ids and records sampled stage spans.
pub struct Tracer {
    /// Sample 1 in `sample` traces; 0 disables tracing entirely.
    sample: u64,
    next: AtomicU64,
    ring: SlotRing<4>,
    epoch: Instant,
}

impl Tracer {
    pub fn new(sample: u64, capacity: usize) -> Self {
        Self {
            sample,
            next: AtomicU64::new(0),
            ring: SlotRing::new(capacity),
            epoch: Instant::now(),
        }
    }

    /// The configured 1-in-N sampling rate (0 = disabled).
    pub fn sample_n(&self) -> u64 {
        self.sample
    }

    /// Mint a fresh trace id: a splitmix64-mixed counter, never 0
    /// (0 on the wire means "untraced"). Returns 0 when tracing is
    /// disabled so downstream hops skip all telemetry with one
    /// branch and the wire frame stays v1-compatible.
    pub fn mint(&self) -> u64 {
        if self.sample == 0 {
            return 0;
        }
        let t = splitmix64(self.next.fetch_add(1, Ordering::Relaxed));
        if t == 0 { 1 } else { t }
    }

    /// The deterministic keep/drop decision for `trace`. This is the
    /// entire overhead of the disabled path.
    #[inline]
    pub fn sampled(&self, trace: u64) -> bool {
        self.sample != 0 && trace != 0 && splitmix64(trace) % self.sample == 0
    }

    /// Nanoseconds since this tracer's epoch for an externally
    /// captured instant (e.g. a request's submit time).
    pub fn ns_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record one stage span if `trace` is sampled.
    pub fn record(&self, trace: u64, stage: Stage, start_ns: u64, dur_ns: u64) {
        if !self.sampled(trace) {
            return;
        }
        self.ring.push([trace, stage as u64, start_ns, dur_ns]);
    }

    /// Copy out the retained spans, oldest first.
    pub fn spans(&self) -> Vec<TraceSpan> {
        self.ring
            .snapshot()
            .into_iter()
            .filter_map(|(_, [trace, stage, start_ns, dur_ns])| {
                Stage::from_u8(stage as u8).map(|stage| TraceSpan { trace, stage, start_ns, dur_ns })
            })
            .collect()
    }

    /// Total spans ever recorded (recorded − capacity = overwritten).
    pub fn recorded(&self) -> u64 {
        self.ring.pushed()
    }

    pub fn capacity(&self) -> usize {
        self.ring.capacity()
    }
}

/// Exact per-stage duration percentiles over a span set (spans are
/// ring-bounded, so sorting is cheap). Returns one summary per stage
/// that appears, in causal stage order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StageSummary {
    pub stage: Stage,
    pub count: usize,
    pub p50_ns: u64,
    pub p90_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
    pub total_ns: u64,
}

pub fn stage_summaries(spans: &[TraceSpan]) -> Vec<StageSummary> {
    let mut out = Vec::new();
    for stage in Stage::ALL {
        let mut durs: Vec<u64> =
            spans.iter().filter(|s| s.stage == stage).map(|s| s.dur_ns).collect();
        if durs.is_empty() {
            continue;
        }
        durs.sort_unstable();
        let pct = |p: f64| durs[((durs.len() - 1) as f64 * p).round() as usize];
        out.push(StageSummary {
            stage,
            count: durs.len(),
            p50_ns: pct(0.50),
            p90_ns: pct(0.90),
            p99_ns: pct(0.99),
            max_ns: *durs.last().unwrap(),
            total_ns: durs.iter().sum(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_mints_zero_and_records_nothing() {
        let t = Tracer::new(0, 16);
        assert_eq!(t.mint(), 0);
        assert!(!t.sampled(12345));
        t.record(12345, Stage::WorkerExec, 0, 10);
        assert!(t.spans().is_empty());
        assert_eq!(t.recorded(), 0);
    }

    #[test]
    fn sample_one_keeps_every_minted_trace() {
        let t = Tracer::new(1, 64);
        for _ in 0..32 {
            let id = t.mint();
            assert_ne!(id, 0);
            assert!(t.sampled(id));
            t.record(id, Stage::TmrVote, t.now_ns(), 5);
        }
        assert_eq!(t.spans().len(), 32);
    }

    #[test]
    fn sampling_is_deterministic_in_the_trace_id() {
        let a = Tracer::new(8, 4);
        let b = Tracer::new(8, 4);
        for id in 1..200u64 {
            assert_eq!(a.sampled(id), b.sampled(id));
            assert_eq!(a.sampled(id), a.sampled(id));
        }
    }

    #[test]
    fn stage_roundtrips_through_u8() {
        for s in Stage::ALL {
            assert_eq!(Stage::from_u8(s as u8), Some(s));
        }
        assert_eq!(Stage::from_u8(7), None);
        assert_eq!(Stage::from_u8(255), None);
    }

    #[test]
    fn summaries_are_exact_over_small_sets() {
        let spans: Vec<TraceSpan> = (1..=100u64)
            .map(|i| TraceSpan { trace: 1, stage: Stage::EccVerify, start_ns: 0, dur_ns: i })
            .collect();
        let s = stage_summaries(&spans);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].stage, Stage::EccVerify);
        assert_eq!(s[0].count, 100);
        assert_eq!(s[0].p50_ns, 51);
        assert_eq!(s[0].max_ns, 100);
        assert_eq!(s[0].total_ns, 5050);
    }
}
