//! Fleet observability: per-request trace spans, the reliability
//! event journal, and the lock-free ring they share.
//!
//! The paper's reliability mechanisms (ECC, TMR, scrubbing, remap)
//! only earn trust at scale if they are observable *in operation* —
//! not just as lifetime aggregate counters, but as *when*, *where in
//! the request path*, and *in what causal order* things happened.
//! This module is that layer, with the same constraints as the rest
//! of the stack: zero dependencies, no allocation on the hot path,
//! and a disabled path that costs a single branch.
//!
//! - [`ring`]: the seqlock-style multi-producer [`ring::SlotRing`].
//! - [`spans`]: u64 trace ids minted at the submitter, deterministic
//!   1-in-N sampling keyed off the id, per-stage [`TraceSpan`]s.
//! - [`journal`]: the bounded [`EventJournal`] of structured
//!   reliability [`Event`]s with monotonic sequence numbers, pulled
//!   fleet-wide over `Events{since}` cursors and merged by the
//!   router with [`merge_events`].
//! - [`wal`]: the durable flight recorder — a checksummed,
//!   segment-rotated append-only log a background flusher spills the
//!   journal into, so a crashed process's story survives for
//!   `remus postmortem`. Each boot mints a fresh random
//!   [`wal::mint_boot_epoch`]; the WAL is forensic, never replayed.

pub mod journal;
pub mod ring;
pub mod spans;
pub mod wal;

pub use journal::{
    merge_events, unix_now_ns, Event, EventJournal, EventKind, DEFAULT_JOURNAL_CAPACITY,
    SHARD_NONE,
};
pub use spans::{
    stage_summaries, Stage, StageSummary, TraceSpan, Tracer, DEFAULT_SPAN_CAPACITY,
};
pub use wal::{
    mint_boot_epoch, read_wal_dir, EpochTimeline, FsyncMode, WalConfig, WalFlusher, WalWriter,
};

/// The splitmix64 finalizer: a cheap, statistically strong u64 mixer.
/// Used both to mint trace ids from a counter and as the sampling
/// hash, so the 1-in-N keep/drop decision is a pure function of the
/// trace id — every hop in the fleet agrees without coordination.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_a_bijection_probe() {
        // Distinct inputs must map to distinct outputs (splitmix64 is
        // invertible); probe a window.
        let mut seen = std::collections::HashSet::new();
        for x in 0..4096u64 {
            assert!(seen.insert(splitmix64(x)));
        }
    }

    #[test]
    fn sampling_rate_is_roughly_one_in_n() {
        let n = 64u64;
        let hits = (0..64_000u64).filter(|&x| splitmix64(splitmix64(x)) % n == 0).count();
        // Expect ~1000; allow a generous band.
        assert!((500..2000).contains(&hits), "1-in-64 sampling badly off: {hits}/64000");
    }
}
