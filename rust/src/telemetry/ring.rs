//! The lock-free slot ring shared by the span buffer and the event
//! journal: a fixed-capacity array of seqlock-style slots written by
//! any number of concurrent producers and snapshotted by readers
//! without ever blocking a writer.
//!
//! Each record is `W` payload words plus a marker. A writer claims a
//! globally unique, monotonically increasing sequence number with one
//! `fetch_add`, picks its slot as `seq % capacity`, parks the marker at
//! 0 ("being written"), stores the payload, then publishes the marker
//! as `seq + 1`. A reader loads the marker, copies the payload, and
//! re-checks the marker: any concurrent overwrite moved it (markers
//! per slot strictly increase by `capacity` per wrap and pass through
//! 0 mid-write), so a torn read is detected and discarded rather than
//! surfaced. Below capacity no two writers ever share a slot, so no
//! record is lost — the property `tests/prop_telemetry.rs` checks
//! under real thread contention.
//!
//! Everything is `SeqCst`: this ring runs only on sampled requests and
//! journal-worthy reliability events (a few per scrub pass), so the
//! fence cost is irrelevant next to the guarantee that the marker
//! protocol is sound under any interleaving.

use std::sync::atomic::{AtomicU64, Ordering};

/// One seqlock-style slot: `marker == 0` means empty or mid-write,
/// `marker == seq + 1` means the payload is record `seq`, complete.
struct Slot<const W: usize> {
    marker: AtomicU64,
    words: [AtomicU64; W],
}

impl<const W: usize> Slot<W> {
    fn new() -> Self {
        Self { marker: AtomicU64::new(0), words: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

/// Fixed-capacity multi-producer ring of `W`-word records.
pub struct SlotRing<const W: usize> {
    slots: Box<[Slot<W>]>,
    next: AtomicU64,
}

impl<const W: usize> SlotRing<W> {
    /// A ring holding the most recent `capacity` records (at least 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            slots: (0..capacity).map(|_| Slot::new()).collect(),
            next: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records ever pushed (the next sequence number).
    pub fn pushed(&self) -> u64 {
        self.next.load(Ordering::SeqCst)
    }

    /// Append a record; returns its sequence number. Never blocks:
    /// past capacity the oldest record in the slot is overwritten.
    pub fn push(&self, words: [u64; W]) -> u64 {
        let seq = self.next.fetch_add(1, Ordering::SeqCst);
        let slot = &self.slots[(seq % self.slots.len() as u64) as usize];
        // Park the marker through 0 so a reader overlapping this write
        // sees the marker move and discards its torn copy.
        slot.marker.store(0, Ordering::SeqCst);
        for (w, &v) in slot.words.iter().zip(&words) {
            w.store(v, Ordering::SeqCst);
        }
        slot.marker.store(seq + 1, Ordering::SeqCst);
        seq
    }

    /// Copy out every complete record, oldest first by sequence number.
    /// Records being overwritten at snapshot time are skipped (their
    /// markers moved), never misread.
    pub fn snapshot(&self) -> Vec<(u64, [u64; W])> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let before = slot.marker.load(Ordering::SeqCst);
            if before == 0 {
                continue;
            }
            let words: [u64; W] = std::array::from_fn(|i| slot.words[i].load(Ordering::SeqCst));
            if slot.marker.load(Ordering::SeqCst) == before {
                out.push((before - 1, words));
            }
        }
        out.sort_unstable_by_key(|&(seq, _)| seq);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn below_capacity_keeps_every_record_in_order() {
        let ring: SlotRing<2> = SlotRing::new(8);
        for i in 0..8u64 {
            assert_eq!(ring.push([i, i * 10]), i);
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 8);
        for (i, (seq, words)) in snap.iter().enumerate() {
            assert_eq!(*seq, i as u64);
            assert_eq!(*words, [i as u64, i as u64 * 10]);
        }
    }

    #[test]
    fn past_capacity_keeps_the_newest_records() {
        let ring: SlotRing<1> = SlotRing::new(4);
        for i in 0..10u64 {
            ring.push([i]);
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4);
        let seqs: Vec<u64> = snap.iter().map(|&(s, _)| s).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "ring keeps the most recent capacity records");
        assert_eq!(ring.pushed(), 10);
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let ring: SlotRing<1> = SlotRing::new(0);
        assert_eq!(ring.capacity(), 1);
        ring.push([42]);
        assert_eq!(ring.snapshot(), vec![(0, [42])]);
    }
}
