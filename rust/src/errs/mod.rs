//! Soft-error models for memristive PIM (paper §II-B).
//!
//! * **Direct** errors strike an *operation*: a stateful gate produces the
//!   wrong output bit (`p_gate`), or a write fails (`p_write`).
//! * **Indirect** errors strike *stored state*: input state-drift on
//!   access (`p_input`), retention drift over time (`lambda_retention`
//!   per bit per second), proximity disturb around writes (`p_proximity`)
//!   and abrupt events such as ion strikes (`lambda_abrupt` per crossbar
//!   per second).
//!
//! The injector is deterministic given (seed, stream): every Monte-Carlo
//! figure in EXPERIMENTS.md reproduces bit-exactly.

pub mod model;
pub mod injector;

pub use injector::{ErrorCounters, Injector};
pub use model::ErrorModel;
