//! Error-model configuration and the device-physics-derived defaults.

/// Probabilities / rates for every soft-error class of paper §II-B.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ErrorModel {
    /// Direct: probability a stateful logic gate's output bit is wrong.
    pub p_gate: f64,
    /// Direct: probability a write (incl. SET init cycles) fails.
    pub p_write: f64,
    /// Indirect: probability an accessed input bit drifts (per access).
    pub p_input: f64,
    /// Indirect: retention flip rate per bit per second.
    pub lambda_retention: f64,
    /// Indirect: probability a write disturbs each physically adjacent cell.
    pub p_proximity: f64,
    /// Indirect: abrupt (ion-strike-like) events per crossbar per second.
    pub lambda_abrupt: f64,
}

impl ErrorModel {
    /// Everything off — the "unreliable baseline" still computes correctly.
    pub fn none() -> Self {
        Self {
            p_gate: 0.0,
            p_write: 0.0,
            p_input: 0.0,
            lambda_retention: 0.0,
            p_proximity: 0.0,
            lambda_abrupt: 0.0,
        }
    }

    /// Only direct gate errors — the Fig. 4 sweep configuration.
    pub fn direct_only(p_gate: f64) -> Self {
        Self { p_gate, ..Self::none() }
    }

    /// Only indirect access errors — the Fig. 5 sweep configuration.
    pub fn indirect_only(p_input: f64) -> Self {
        Self { p_input, ..Self::none() }
    }

    /// A "nominal technology" point assembled from the literature the
    /// paper cites (RRAM variability studies): used by examples as a
    /// realistic default.
    pub fn nominal() -> Self {
        Self {
            p_gate: 1e-9,
            p_write: 1e-10,
            p_input: 1e-10,
            lambda_retention: 1e-12,
            p_proximity: 1e-11,
            lambda_abrupt: 1e-9,
        }
    }

    pub fn is_silent(&self) -> bool {
        self.p_gate == 0.0
            && self.p_write == 0.0
            && self.p_input == 0.0
            && self.lambda_retention == 0.0
            && self.p_proximity == 0.0
            && self.lambda_abrupt == 0.0
    }
}

impl Default for ErrorModel {
    fn default() -> Self {
        Self::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        assert!(ErrorModel::none().is_silent());
        let d = ErrorModel::direct_only(1e-6);
        assert_eq!(d.p_gate, 1e-6);
        assert_eq!(d.p_input, 0.0);
        assert!(!d.is_silent());
        let i = ErrorModel::indirect_only(1e-7);
        assert_eq!(i.p_input, 1e-7);
        assert_eq!(i.p_gate, 0.0);
        assert!(!ErrorModel::nominal().is_silent());
    }
}
