//! The error injector: deterministic sampling of soft-error events.
//!
//! Hot-path design: error positions are sampled by geometric skipping
//! (`Pcg64::geometric`), so a clean gate over 1024 lanes costs O(1)
//! expected work at realistic p (1e-9..1e-4) instead of 1024 Bernoulli
//! draws. This is what keeps reliability *on* cheap (EXPERIMENTS.md §Perf).

use crate::util::rng::Pcg64;

use super::model::ErrorModel;

/// Tally of injected events, by class — examples and tests assert on it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ErrorCounters {
    pub gate_flips: u64,
    pub write_fails: u64,
    pub input_drifts: u64,
    pub retention_flips: u64,
    pub proximity_flips: u64,
    pub abrupt_flips: u64,
}

impl ErrorCounters {
    pub fn total(&self) -> u64 {
        self.gate_flips
            + self.write_fails
            + self.input_drifts
            + self.retention_flips
            + self.proximity_flips
            + self.abrupt_flips
    }
}

/// Deterministic soft-error sampler.
#[derive(Clone, Debug)]
pub struct Injector {
    pub model: ErrorModel,
    rng: Pcg64,
    pub counters: ErrorCounters,
}

impl Injector {
    pub fn new(model: ErrorModel, seed: u64, stream: u64) -> Self {
        Self { model, rng: Pcg64::new(seed, stream), counters: ErrorCounters::default() }
    }

    /// Derive an injector with an independent stream (per worker/crossbar).
    pub fn split(&mut self) -> Injector {
        Injector { model: self.model, rng: self.rng.split(), counters: ErrorCounters::default() }
    }

    /// Visit the indices in `0..n` where an independent Bernoulli(p) trial
    /// fires, in increasing order (geometric skip sampling).
    #[inline]
    pub fn for_each_hit(&mut self, n: usize, p: f64, mut f: impl FnMut(usize)) {
        if p <= 0.0 || n == 0 {
            return;
        }
        let mut i = self.rng.geometric(p);
        while (i as usize) < n {
            f(i as usize);
            i = i.saturating_add(1 + self.rng.geometric(p));
        }
    }

    /// Direct gate-output flips for one micro-op across `lanes` lanes.
    pub fn gate_flips(&mut self, lanes: usize, mut flip: impl FnMut(usize)) {
        let p = self.model.p_gate;
        let mut count = 0;
        self.for_each_hit(lanes, p, |i| {
            flip(i);
            count += 1;
        });
        self.counters.gate_flips += count;
    }

    /// Write failures (SET init cycles and explicit writes).
    pub fn write_fails(&mut self, lanes: usize, mut flip: impl FnMut(usize)) {
        let p = self.model.p_write;
        let mut count = 0;
        self.for_each_hit(lanes, p, |i| {
            flip(i);
            count += 1;
        });
        self.counters.write_fails += count;
    }

    /// Indirect input state-drift: each of the `bits` accessed input bits
    /// flips with `p_input`. Caller maps the flat hit index back to
    /// (operand, lane).
    pub fn input_drifts(&mut self, bits: usize, mut flip: impl FnMut(usize)) {
        let p = self.model.p_input;
        let mut count = 0;
        self.for_each_hit(bits, p, |i| {
            flip(i);
            count += 1;
        });
        self.counters.input_drifts += count;
    }

    /// Retention over `dt` seconds across `bits` stored bits:
    /// each bit flips with prob `1 - exp(-lambda * dt)`.
    pub fn retention(&mut self, bits: usize, dt: f64, mut flip: impl FnMut(usize)) {
        let lam = self.model.lambda_retention;
        if lam <= 0.0 || dt <= 0.0 {
            return;
        }
        let p = -(-lam * dt).exp_m1();
        let mut count = 0;
        self.for_each_hit(bits, p, |i| {
            flip(i);
            count += 1;
        });
        self.counters.retention_flips += count;
    }

    /// Proximity disturb on `neighbors` cells adjacent to a write.
    pub fn proximity(&mut self, neighbors: usize, mut flip: impl FnMut(usize)) {
        let p = self.model.p_proximity;
        let mut count = 0;
        self.for_each_hit(neighbors, p, |i| {
            flip(i);
            count += 1;
        });
        self.counters.proximity_flips += count;
    }

    /// Abrupt events over `dt` seconds: Poisson(lambda_abrupt * dt) strikes,
    /// each hitting a uniformly random bit of `bits`.
    pub fn abrupt(&mut self, bits: usize, dt: f64, mut flip: impl FnMut(usize)) {
        let lam = self.model.lambda_abrupt * dt;
        if lam <= 0.0 || bits == 0 {
            return;
        }
        let strikes = self.poisson(lam);
        for _ in 0..strikes {
            flip(self.rng.below(bits as u64) as usize);
        }
        self.counters.abrupt_flips += strikes;
    }

    fn poisson(&mut self, lam: f64) -> u64 {
        if lam < 30.0 {
            // Knuth's method.
            let l = (-lam).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.rng.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lam + lam.sqrt() * self.rng.gaussian();
            x.max(0.0).round() as u64
        }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn reset_counters(&mut self) {
        self.counters = ErrorCounters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silent_model_never_fires() {
        let mut inj = Injector::new(ErrorModel::none(), 1, 0);
        let mut hits = 0;
        for _ in 0..1000 {
            inj.gate_flips(1024, |_| hits += 1);
            inj.input_drifts(1024, |_| hits += 1);
            inj.retention(1024, 1.0, |_| hits += 1);
            inj.abrupt(1024, 1.0, |_| hits += 1);
        }
        assert_eq!(hits, 0);
        assert_eq!(inj.counters.total(), 0);
    }

    #[test]
    fn gate_flip_rate_matches_p() {
        let p = 1e-3;
        let mut inj = Injector::new(ErrorModel::direct_only(p), 7, 0);
        let lanes = 1024;
        let trials = 20_000;
        for _ in 0..trials {
            inj.gate_flips(lanes, |i| assert!(i < lanes));
        }
        let rate = inj.counters.gate_flips as f64 / (lanes as f64 * trials as f64);
        assert!((rate - p).abs() / p < 0.05, "rate={rate}");
    }

    #[test]
    fn hits_are_sorted_unique() {
        let mut inj = Injector::new(ErrorModel::direct_only(0.3), 3, 1);
        for _ in 0..100 {
            let mut last = -1i64;
            inj.gate_flips(256, |i| {
                assert!((i as i64) > last, "hits must be strictly increasing");
                last = i as i64;
            });
        }
    }

    #[test]
    fn retention_rate() {
        let lam = 1e-4;
        let dt = 100.0;
        let model = ErrorModel { lambda_retention: lam, ..ErrorModel::none() };
        let mut inj = Injector::new(model, 11, 0);
        let bits = 100_000;
        inj.retention(bits, dt, |_| {});
        let expect = bits as f64 * (1.0 - (-lam * dt as f64).exp());
        let got = inj.counters.retention_flips as f64;
        assert!((got - expect).abs() < expect * 0.2 + 10.0, "got={got} expect={expect}");
    }

    #[test]
    fn abrupt_poisson_mean() {
        let model = ErrorModel { lambda_abrupt: 2.0, ..ErrorModel::none() };
        let mut inj = Injector::new(model, 13, 0);
        let trials = 5_000;
        for _ in 0..trials {
            inj.abrupt(4096, 1.0, |i| assert!(i < 4096));
        }
        let mean = inj.counters.abrupt_flips as f64 / trials as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Injector::new(ErrorModel::direct_only(0.01), 5, 2);
        let mut b = Injector::new(ErrorModel::direct_only(0.01), 5, 2);
        let mut ha = vec![];
        let mut hb = vec![];
        for _ in 0..50 {
            a.gate_flips(4096, |i| ha.push(i));
            b.gate_flips(4096, |i| hb.push(i));
        }
        assert_eq!(ha, hb);
        assert!(!ha.is_empty());
    }
}
