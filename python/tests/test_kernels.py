"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes, opcodes, indices and bit contents; allclose with
atol=0 is intentional — these kernels compute exact {0,1} arithmetic, so
bit-exact agreement is required.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gate_step import gate_step
from compile.kernels.vote import vote3
from compile.kernels.diag_parity import diag_parity
from compile.kernels.matmul_fi import matmul_fi

SHAPES = st.sampled_from([(8, 8), (16, 32), (64, 64), (128, 16)])


def bits(rng, shape):
    return (rng.random(shape) < 0.5).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(
    shape=SHAPES,
    op=st.integers(0, ref.NUM_OPCODES - 1),
    seed=st.integers(0, 2**31 - 1),
    with_err=st.booleans(),
)
def test_gate_step_matches_ref(shape, op, seed, with_err):
    r, c = shape
    rng = np.random.default_rng(seed)
    state = bits(rng, (r, c))
    idx = rng.integers(0, c, size=4).astype(np.int32)
    err = bits(rng, (r,)) if with_err else np.zeros((r,), np.float32)
    got = gate_step(jnp.asarray(state), jnp.int32(op), jnp.asarray(idx), jnp.asarray(err), block_r=min(r, 32))
    want = ref.gate_step_ref(jnp.asarray(state), jnp.int32(op), jnp.asarray(idx), jnp.asarray(err))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


@settings(max_examples=20, deadline=None)
@given(shape=SHAPES, steps=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
def test_gate_program_matches_ref(shape, steps, seed):
    """A random micro-op program, applied step by step, matches the oracle."""
    r, c = shape
    rng = np.random.default_rng(seed)
    state = bits(rng, (r, c))
    ops = rng.integers(0, ref.NUM_OPCODES, size=steps).astype(np.int32)
    idxs = rng.integers(0, c, size=(steps, 4)).astype(np.int32)
    errs = (rng.random((steps, r)) < 0.05).astype(np.float32)
    got = jnp.asarray(state)
    for s in range(steps):
        got = gate_step(got, jnp.int32(ops[s]), jnp.asarray(idxs[s]), jnp.asarray(errs[s]), block_r=min(r, 32))
    want = ref.gate_scan_ref(jnp.asarray(state), ops, idxs, errs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


@settings(max_examples=30, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**31 - 1), faulty=st.booleans())
def test_vote3_matches_ref(shape, seed, faulty):
    rng = np.random.default_rng(seed)
    a, b, c = (bits(rng, shape) for _ in range(3))
    if faulty:
        em, en = (rng.random(shape) < 0.1).astype(np.float32), (rng.random(shape) < 0.1).astype(np.float32)
    else:
        em = en = np.zeros(shape, np.float32)
    got = vote3(*map(jnp.asarray, (a, b, c, em, en)), block_r=min(shape[0], 32))
    want = ref.vote3_ref(*map(jnp.asarray, (a, b, c, em, en)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


def test_vote3_clean_is_majority():
    """With clean gates, vote3 is exactly per-bit majority."""
    rng = np.random.default_rng(7)
    a, b, c = (bits(rng, (32, 32)) for _ in range(3))
    z = np.zeros((32, 32), np.float32)
    got = np.asarray(vote3(*map(jnp.asarray, (a, b, c, z, z))))
    want = ((a + b + c) >= 2).astype(np.float32)
    np.testing.assert_allclose(got, want, atol=0)


def test_vote3_paper_example():
    """Paper Section V: voting 1000 / 0100 / 0010 per-bit yields 0000."""
    a = jnp.asarray([[1.0, 0.0, 0.0, 0.0]])
    b = jnp.asarray([[0.0, 1.0, 0.0, 0.0]])
    c = jnp.asarray([[0.0, 0.0, 1.0, 0.0]])
    z = jnp.zeros((1, 4))
    got = np.asarray(vote3(a, b, c, z, z, block_r=1))
    np.testing.assert_allclose(got, np.zeros((1, 4)), atol=0)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 16),
    m=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_diag_parity_matches_ref(b, m, seed):
    rng = np.random.default_rng(seed)
    blocks = bits(rng, (b, m, m))
    got = diag_parity(jnp.asarray(blocks))
    want = ref.diag_parity_ref(jnp.asarray(blocks))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


def test_diag_parity_single_flip_localizes():
    """A single bit flip fails exactly one leading and one counter diagonal,
    and their intersection identifies the flipped cell (the paper's
    multidimensional-parity correction argument)."""
    m = 8
    rng = np.random.default_rng(3)
    blk = bits(rng, (1, m, m))
    base = np.asarray(diag_parity(jnp.asarray(blk)))[0]
    for (i, j) in [(0, 0), (3, 5), (7, 7), (2, 6)]:
        flipped = blk.copy()
        flipped[0, i, j] = 1.0 - flipped[0, i, j]
        par = np.asarray(diag_parity(jnp.asarray(flipped)))[0]
        diff = np.nonzero(par != base)[0]
        assert len(diff) == 2
        lead_d, cnt_d = diff[0], diff[1] - m
        assert lead_d == (j - i) % m  # cell (i,j) lies on leading diagonal (j-i) mod m
        assert cnt_d == (i + j) % m  # ... and counter diagonal (i+j) mod m


@settings(max_examples=30, deadline=None)
@given(
    dims=st.sampled_from([(8, 8, 8), (16, 32, 16), (64, 64, 64), (32, 16, 64)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_fi_matches_ref(dims, seed):
    b, k, n = dims
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    mm = (rng.random((k, n)) < 0.9).astype(np.float32)
    ma = (rng.random((k, n)) < 0.05).astype(np.float32) * rng.standard_normal((k, n)).astype(np.float32)
    got = matmul_fi(*map(jnp.asarray, (x, w, mm, ma)), bm=min(b, 16), bn=min(n, 16))
    want = ref.matmul_fi_ref(*map(jnp.asarray, (x, w, mm, ma)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matmul_fi_identity_masks_are_clean():
    rng = np.random.default_rng(11)
    x = rng.standard_normal((16, 8)).astype(np.float32)
    w = rng.standard_normal((8, 16)).astype(np.float32)
    got = matmul_fi(jnp.asarray(x), jnp.asarray(w), jnp.ones((8, 16)), jnp.zeros((8, 16)), bm=16, bn=16)
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-5, atol=1e-5)
