"""Layer-2 correctness: the scan executor and MicroNet graph vs oracles."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def bits(rng, shape):
    return (rng.random(shape) < 0.5).astype(np.float32)


@settings(max_examples=15, deadline=None)
@given(
    shape=st.sampled_from([(8, 8), (32, 32), (64, 16)]),
    steps=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_gate_scan_matches_ref(shape, steps, seed):
    r, c = shape
    rng = np.random.default_rng(seed)
    state = bits(rng, (r, c))
    ops = rng.integers(0, ref.NUM_OPCODES, size=steps).astype(np.int32)
    idxs = rng.integers(0, c, size=(steps, 4)).astype(np.int32)
    errs = (rng.random((steps, r)) < 0.03).astype(np.float32)
    (got,) = model.gate_scan(*map(jnp.asarray, (state, ops, idxs, errs)))
    want = ref.gate_scan_ref(jnp.asarray(state), ops, idxs, errs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


def test_gate_scan_nop_padding_is_identity():
    """Programs are NOP-padded to the artifact's static S; padding must not
    disturb state (this is what lets rust reuse one artifact for any
    program length)."""
    rng = np.random.default_rng(5)
    state = bits(rng, (16, 16))
    s = 20
    ops = np.zeros((s,), np.int32)  # all NOP
    idxs = rng.integers(0, 16, size=(s, 4)).astype(np.int32)
    errs = np.ones((s, 16), np.float32)  # even with err=1: NOP never injects
    (got,) = model.gate_scan(*map(jnp.asarray, (state, ops, idxs, errs)))
    np.testing.assert_allclose(np.asarray(got), state, atol=0)


def test_gate_scan_full_adder():
    """A hand-mapped MAGIC/FELIX 1-bit full adder, row-parallel over all
    2^3 input combinations at once (one combination per row) — the Fig. 1
    row-parallelism claim, exercised through the L2 executor.

    Layout: col0=a, col1=b, col2=cin, cols 3.. intermediates/outputs.
    sum  = MIN3(a, b, cin) XOR NOT(MIN3(...)) composition:
      maj = NOT(MIN3(a,b,cin))            -> carry-out
      sum = MIN3(a, b, cin) and XOR trick: sum = MIN3(maj, MIN3(a,b,cin)...)
    We use the textbook FELIX mapping:
      t0 = MIN3(a, b, c)        (= !maj = !carry)
      cout = NOT(t0)
      t1 = MIN3(a, b, t0), t2 = MIN3(a, c, t0), t3 = MIN3(b, c, t0)
      sum = MIN3(t1, t2, t3) ... (verified against truth table below)
    """
    NOP, NOT, NOR2, NOR3, OR2, NAND2, MIN3, SET1, SET0 = range(ref.NUM_OPCODES)
    rows = 8
    cols = 16
    state = np.zeros((rows, cols), np.float32)
    for rix in range(8):
        a, b, c = (rix >> 2) & 1, (rix >> 1) & 1, rix & 1
        state[rix, 0], state[rix, 1], state[rix, 2] = a, b, c

    prog = [
        (MIN3, 0, 1, 2, 3),  # t0 = !maj(a,b,c)
        (NOT, 3, 0, 0, 4),  # cout = maj
        (MIN3, 0, 1, 3, 5),  # t1
        (MIN3, 0, 2, 3, 6),  # t2
        (MIN3, 1, 2, 3, 7),  # t3
        (MIN3, 5, 6, 7, 8),  # sum
    ]
    ops = np.array([p[0] for p in prog], np.int32)
    idxs = np.array([[p[1], p[2], p[3], p[4]] for p in prog], np.int32)
    errs = np.zeros((len(prog), rows), np.float32)
    (out,) = model.gate_scan(*map(jnp.asarray, (state, ops, idxs, errs)))
    out = np.asarray(out)
    for rix in range(8):
        a, b, c = (rix >> 2) & 1, (rix >> 1) & 1, rix & 1
        assert out[rix, 4] == float((a + b + c) >= 2), f"cout row {rix}"
        assert out[rix, 8] == float((a + b + c) % 2), f"sum row {rix}"


def test_micronet_fwd_matches_ref():
    rng = np.random.default_rng(9)
    b, ind, h, out = 8, 64, 32, 10
    x = rng.standard_normal((b, ind)).astype(np.float32)
    w1 = rng.standard_normal((ind, h)).astype(np.float32)
    b1 = rng.standard_normal((h,)).astype(np.float32)
    w2 = rng.standard_normal((h, out)).astype(np.float32)
    b2 = rng.standard_normal((out,)).astype(np.float32)
    m1 = (rng.random((ind, h)) < 0.95).astype(np.float32)
    a1 = np.zeros((ind, h), np.float32)
    m2 = np.ones((h, out), np.float32)
    a2 = (rng.random((h, out)) < 0.05).astype(np.float32)
    (got,) = model.micronet_fwd(*map(jnp.asarray, (x, w1, b1, w2, b2, m1, a1, m2, a2)))
    want = ref.micronet_fwd_ref(*map(jnp.asarray, (x, w1, b1, w2, b2, m1, a1, m2, a2)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_micronet_trains_to_high_accuracy():
    """Build-time training sanity: MicroNet must comfortably beat 90 % on
    the held-out synthetic digit set (the E2E example interprets accuracy
    drops vs this clean baseline)."""
    from compile import train

    params, (xev, yev), acc = train.train()
    assert acc > 0.9, acc
