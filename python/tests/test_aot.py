"""AOT pipeline tests: HLO-text emission, manifest contract, and the
training exporter's binary formats (the rust side parses these)."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model, train


def test_to_hlo_text_is_parseable_hlo(tmp_path):
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # Text (not proto) is the interchange format — see aot.py docstring.
    assert not text.startswith(b"\x08".decode("latin1"))


def test_emit_writes_file_and_manifest(tmp_path):
    manifest = []
    aot.emit(
        str(tmp_path),
        "gate_scan_r8_c8_s4",
        model.gate_scan,
        (
            jax.ShapeDtypeStruct((8, 8), jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.int32),
            jax.ShapeDtypeStruct((4, 4), jnp.int32),
            jax.ShapeDtypeStruct((4, 8), jnp.float32),
        ),
        manifest,
        kind="gate_scan",
        r=8,
        c=8,
        s=4,
    )
    assert (tmp_path / "gate_scan_r8_c8_s4.hlo.txt").exists()
    assert len(manifest) == 1
    line = manifest[0]
    assert line.startswith("artifact name=gate_scan_r8_c8_s4")
    assert "kind=gate_scan" in line and "r=8" in line and "s=4" in line
    # Each field is a single key=value token (the rust parser contract).
    for token in line.split()[1:]:
        assert "=" in token, token


def test_weights_export_roundtrip(tmp_path):
    acc = train.export(str(tmp_path))
    assert acc > 0.9
    w = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
    expected = train.IN_DIM * train.HIDDEN + train.HIDDEN + train.HIDDEN * train.N_CLASSES + train.N_CLASSES
    assert w.shape[0] == expected
    e = np.fromfile(tmp_path / "evalset.bin", dtype="<f4")
    assert e.shape[0] == train.N_EVAL * train.IN_DIM + train.N_EVAL
    labels = e[train.N_EVAL * train.IN_DIM :]
    assert labels.min() >= 0 and labels.max() < train.N_CLASSES
    assert np.all(labels == labels.astype(int))


def test_built_artifacts_manifest_consistent():
    """When artifacts/ exists (make artifacts), every manifest entry must
    point at an existing file with consistent declared shapes."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(root, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    with open(manifest) as f:
        lines = [l.strip() for l in f if l.strip()]
    assert len(lines) >= 8
    kinds = set()
    for line in lines:
        fields = dict(kv.split("=", 1) for kv in line.split()[1:])
        assert os.path.exists(os.path.join(root, fields["file"])), fields["file"]
        if line.startswith("artifact"):
            kinds.add(fields["kind"])
            if fields["kind"] == "gate_scan":
                name = fields["name"]
                assert f"r{fields['r']}" in name and f"s{fields['s']}" in name
    assert {"gate_scan", "vote3", "diag_parity", "micronet"} <= kinds
