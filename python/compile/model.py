"""Layer-2 JAX compute graphs (build-time only; AOT-lowered by aot.py).

Two graph families, both calling the Layer-1 Pallas kernels:

* `gate_scan` — the vectorized crossbar program executor: a `lax.scan`
  over an encoded micro-op program, each step applying the row-parallel
  Pallas gate kernel to the full crossbar state. This is what lets the
  rust coordinator run an entire in-memory arithmetic function (e.g. a
  32-bit MultPIM multiplication across all rows) in ONE PJRT call.
* `micronet_fwd` — the case-study MLP forward pass with per-layer weight
  fault masks (paper Section VI), built from the fault-masked matmul
  kernel.

Everything is static-shape: aot.py lowers one HLO artifact per
(R, C, S) / (B, H) configuration listed in its manifest.
"""

import jax
import jax.numpy as jnp

from .kernels import gate_step as k_gate
from .kernels import matmul_fi as k_mm
from .kernels import vote as k_vote
from .kernels import diag_parity as k_diag
from .kernels import ref


def gate_scan(state, ops, idxs, errs):
    """Execute a padded micro-op program on the crossbar state.

    state: (R, C) f32 {0,1}
    ops:   (S,)   i32 opcodes (ref.NOP pads)
    idxs:  (S, 4) i32 [i1, i2, i3, out]
    errs:  (S, R) f32 direct-soft-error flip masks (zeros = clean run)
    Returns the final (R, C) state. Semantics == ref.gate_scan_ref.
    """

    def step(s, xs):
        op, idx, err = xs
        return k_gate.gate_step(s, op, idx, err), ()

    final, _ = jax.lax.scan(step, state, (ops, idxs, errs))
    return (final,)


def vote3(a, b, c, err_min, err_not):
    """Per-bit TMR majority vote of three state planes (faulty gates)."""
    return (k_vote.vote3(a, b, c, err_min, err_not),)


def diag_parity(blocks):
    """ECC diagonal check-bit computation for a batch of m x m blocks."""
    return (k_diag.diag_parity(blocks),)


def micronet_fwd(x, w1, b1, w2, b2, m1, a1, m2, a2):
    """Fault-injected MicroNet forward: logits (B, 10).

    x: (B, 64); w1: (64, H); w2: (H, 10); m*/a* are the per-layer
    multiplicative/additive weight fault masks (identity = clean).
    """
    h = jnp.maximum(k_mm.matmul_fi(x, w1, m1, a1) + b1[None, :], 0.0)
    logits = k_mm.matmul_fi(h, w2, m2, a2) + b2[None, :]
    return (logits,)


def micronet_fwd_clean_ref(x, w1, b1, w2, b2):
    """Mask-free oracle used by tests and by train.py evaluation."""
    ones1, zeros1 = jnp.ones_like(w1), jnp.zeros_like(w1)
    ones2, zeros2 = jnp.ones_like(w2), jnp.zeros_like(w2)
    return ref.micronet_fwd_ref(x, w1, b1, w2, b2, ones1, zeros1, ones2, zeros2)
