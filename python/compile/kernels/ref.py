"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: every Pallas kernel in
this package is checked against the corresponding function here by pytest
(hypothesis sweeps shapes / contents) before anything is AOT-lowered.

Bit convention: logical state is carried in float32 with values in {0.0, 1.0}
(low resistance = 1, high resistance = 0). f32 is used because the PJRT
interchange path (rust `xla` crate) round-trips f32 literals natively.

Micro-op encoding (MUST match `rust/src/isa/encode.rs`):

    opcode  semantics (row-parallel, in-row gate at columns i1,i2,i3 -> out)
    ------  ------------------------------------------------------------
    0 NOP   state unchanged (padding)
    1 NOT   out = !i1                      (MAGIC NOT)
    2 NOR2  out = !(i1 | i2)               (MAGIC NOR)
    3 NOR3  out = !(i1 | i2 | i3)          (MAGIC 3-input NOR)
    4 OR2   out = i1 | i2                  (FELIX OR)
    5 NAND2 out = !(i1 & i2)               (FELIX NAND)
    6 MIN3  out = !maj(i1, i2, i3)         (FELIX Minority3)
    7 SET1  out = 1                        (output initialization)
    8 SET0  out = 0

Direct soft errors are injected as an XOR flip mask on the produced output
column (one bit per row per step), exactly the `p_gate` model of the paper
(Section II-B "incorrect logic").
"""

import jax.numpy as jnp

NUM_OPCODES = 9
(NOP, NOT, NOR2, NOR3, OR2, NAND2, MIN3, SET1, SET0) = range(NUM_OPCODES)


def fxor(a, b):
    """XOR for {0,1}-valued floats."""
    return a + b - 2.0 * a * b


def gate_eval_ref(op, v1, v2, v3):
    """Evaluate one stateful gate on {0,1} float operands (vectorized).

    `op` is a scalar int; v1/v2/v3 are (R,) float arrays.
    """
    or2 = v1 + v2 - v1 * v2
    or3 = or2 + v3 - or2 * v3
    maj = v1 * v2 + v1 * v3 + v2 * v3 - 2.0 * v1 * v2 * v3
    ones = jnp.ones_like(v1)
    zeros = jnp.zeros_like(v1)
    table = jnp.stack(
        [
            v1,  # NOP placeholder (unused: NOP keeps old column)
            1.0 - v1,  # NOT
            1.0 - or2,  # NOR2
            1.0 - or3,  # NOR3
            or2,  # OR2
            1.0 - v1 * v2,  # NAND2
            1.0 - maj,  # MIN3
            ones,  # SET1
            zeros,  # SET0
        ]
    )
    return table[op]


def gate_step_ref(state, op, idx, err):
    """One row-parallel stateful-gate step on the whole crossbar.

    state: (R, C) float {0,1};  op: scalar int32;  idx: (4,) int32
    [i1, i2, i3, out];  err: (R,) float {0,1} flip mask applied to the
    produced output (direct soft error model).
    Returns the new (R, C) state.
    """
    i1, i2, i3, out = idx[0], idx[1], idx[2], idx[3]
    v1 = state[:, i1]
    v2 = state[:, i2]
    v3 = state[:, i3]
    res = gate_eval_ref(op, v1, v2, v3)
    res = fxor(res, err)
    newcol = jnp.where(op == NOP, state[:, out], res)
    return state.at[:, out].set(newcol)


def gate_scan_ref(state, ops, idxs, errs):
    """Execute a full micro-op program (the L2 executor semantics).

    ops: (S,) int32;  idxs: (S, 4) int32;  errs: (S, R) float.
    """
    for s in range(ops.shape[0]):
        state = gate_step_ref(state, ops[s], idxs[s], errs[s])
    return state


def vote3_ref(a, b, c, err_min, err_not):
    """Per-bit TMR voting via the in-memory Minority3 gate + NOT.

    maj(a,b,c) is realized as NOT(Minority3(a,b,c)); both stateful gates
    are themselves vulnerable, hence the two flip masks (paper Section V:
    "also vulnerable to soft-errors").
    All arrays (R, C) float {0,1}.
    """
    maj = a * b + a * c + b * c - 2.0 * a * b * c
    minority = fxor(1.0 - maj, err_min)
    return fxor(1.0 - minority, err_not)


def diag_parity_ref(blocks):
    """Leading + counter wrap-around diagonal parities per m x m block.

    blocks: (B, m, m) float {0,1}.
    Returns (B, 2m): [:, :m] leading parities  lead[d] = XOR_i b[i, (i+d)%m]
                     [:, m:] counter parities  cnt[d]  = XOR_i b[i, (d-i)%m]
    This is the diagonal check-bit pattern of Fig. 2(b,c): each output is
    what the barrel shifter accumulates along one wrap-around diagonal.
    """
    B, m, _ = blocks.shape
    i = jnp.arange(m)[:, None]
    d = jnp.arange(m)[None, :]
    lead_cols = (i + d) % m  # (m, m): column of row i on leading diag d
    cnt_cols = (d - i) % m
    lead_bits = jnp.take_along_axis(blocks, jnp.broadcast_to(lead_cols, (B, m, m)), axis=2)
    cnt_bits = jnp.take_along_axis(blocks, jnp.broadcast_to(cnt_cols, (B, m, m)), axis=2)
    lead = jnp.mod(jnp.sum(lead_bits, axis=1), 2.0)
    cnt = jnp.mod(jnp.sum(cnt_bits, axis=1), 2.0)
    return jnp.concatenate([lead, cnt], axis=1)


def matmul_fi_ref(x, w, mmul, madd):
    """Fault-injected matmul: y = x @ (w * mmul + madd).

    The multiplicative/additive masks model value-level corruption of the
    weight operands caused by direct soft errors in the in-memory
    multiplier (rust generates them from bit-flip models on the Q16.16
    encoding). Identity masks (mmul=1, madd=0) give a clean matmul.
    """
    return x @ (w * mmul + madd)


def micronet_fwd_ref(x, w1, b1, w2, b2, m1, a1, m2, a2):
    """Case-study MicroNet forward pass (64 -> H -> 10 MLP, relu),
    with per-layer weight fault masks."""
    h = jnp.maximum(matmul_fi_ref(x, w1, m1, a1) + b1, 0.0)
    return matmul_fi_ref(h, w2, m2, a2) + b2
