"""Layer-1 Pallas kernel: fault-injected matmul for the NN case study.

y = x @ (w * mmul + madd): the masks perturb weight operands at value
level, modeling direct soft errors in the in-memory (MultPIM) multiplier
during a FloatPIM-style feed-forward pass. The rust campaign driver
generates masks from bit-flip models on the Q16.16 encoding and sweeps
p_gate (paper Fig. 4 bottom).

Classic MXU tiling: grid over (rows of x) x (cols of w); the full K
dimension stays resident (K <= 64 for MicroNet). VMEM per step:
(BM*K + K*BN * 3 + BM*BN) * 4 B.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 64
DEFAULT_BN = 64


def _matmul_fi_kernel(x_ref, w_ref, mm_ref, ma_ref, out_ref):
    w_eff = w_ref[...] * mm_ref[...] + ma_ref[...]
    out_ref[...] = jnp.dot(x_ref[...], w_eff, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul_fi(x, w, mmul, madd, *, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """(B, K) @ fault-masked (K, N) -> (B, N). Matches `ref.matmul_fi_ref`."""
    b, k = x.shape
    _, n = w.shape
    bm = min(bm, b)
    bn = min(bn, n)
    assert b % bm == 0 and n % bn == 0, (b, n, bm, bn)
    wspec = pl.BlockSpec((k, bn), lambda i, j: (0, j))
    return pl.pallas_call(
        _matmul_fi_kernel,
        grid=(b // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)), wspec, wspec, wspec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, w, mmul, madd)
