"""Layer-1 Pallas kernel: one row-parallel stateful-gate step.

This is the compute hot-spot of the whole stack: a single crossbar cycle
applies the same in-row gate across *all* rows simultaneously (Fig. 1a of
the paper). On the crossbar that parallelism is free; here it maps onto the
TPU as follows (DESIGN.md "Hardware adaptation"):

* operand gather  `V = S @ sel^T`  — a (block_R, C) x (C, 4) matmul on the
  MXU (sel holds one-hot column selectors for i1, i2, i3, out);
* gate evaluation — branchless VPU arithmetic over the four (block_R,)
  operand vectors, blended by a one-hot opcode vector;
* error injection — XOR with the per-row flip mask (`p_gate` model);
* scatter         — rank-1 update `S' = S + (res - old) outer out_sel`,
  again MXU/VPU friendly (no dynamic indexing inside the kernel).

The kernel is tiled over rows with BlockSpec: each grid step holds one
(BLOCK_R, C) state tile plus the (C, 4) selector in VMEM. VMEM footprint
is ~ (BLOCK_R * C + C * 4 + 5 * BLOCK_R) * 4 B; with BLOCK_R = 128 and
C = 1024 that is ~0.5 MiB << 16 MiB, leaving room for double buffering.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_R = 128


def _gate_step_kernel(sel_ref, opv_ref, state_ref, err_ref, out_ref):
    """One (BLOCK_R, C) tile of the crossbar state.

    sel_ref: (C, 4) one-hot selectors [i1 | i2 | i3 | out]
    opv_ref: (NUM_OPCODES,) one-hot opcode
    state_ref: (BLOCK_R, C) state tile;  err_ref: (BLOCK_R,) flip mask
    out_ref: (BLOCK_R, C) new state tile
    """
    s = state_ref[...]
    sel = sel_ref[...]
    opv = opv_ref[...]
    err = err_ref[...]

    # MXU gather: (BLOCK_R, C) @ (C, 4) -> (BLOCK_R, 4)
    v = jnp.dot(s, sel, preferred_element_type=jnp.float32)
    v1, v2, v3, old = v[:, 0], v[:, 1], v[:, 2], v[:, 3]

    or2 = v1 + v2 - v1 * v2
    or3 = or2 + v3 - or2 * v3
    maj = v1 * v2 + v1 * v3 + v2 * v3 - 2.0 * v1 * v2 * v3

    # Branchless opcode blend (opv is one-hot over ref.NUM_OPCODES).
    res = (
        opv[ref.NOP] * old
        + opv[ref.NOT] * (1.0 - v1)
        + opv[ref.NOR2] * (1.0 - or2)
        + opv[ref.NOR3] * (1.0 - or3)
        + opv[ref.OR2] * or2
        + opv[ref.NAND2] * (1.0 - v1 * v2)
        + opv[ref.MIN3] * (1.0 - maj)
        + opv[ref.SET1] * 1.0
        + opv[ref.SET0] * 0.0
    )
    # Direct soft error: flip produced bit where err == 1 (never on NOP).
    res = res + (1.0 - opv[ref.NOP]) * (err - 2.0 * res * err)

    # Rank-1 scatter back into the out column.
    out_sel = sel[:, 3]  # (C,)
    out_ref[...] = s + (res - old)[:, None] * out_sel[None, :]


@functools.partial(jax.jit, static_argnames=("block_r",))
def gate_step(state, op, idx, err, *, block_r=DEFAULT_BLOCK_R):
    """Apply one micro-op to the full (R, C) crossbar state.

    state: (R, C) f32 {0,1};  op: scalar int32;  idx: (4,) int32
    [i1,i2,i3,out];  err: (R,) f32 flip mask. Returns new state.
    Matches `ref.gate_step_ref` bit-exactly.
    """
    r, c = state.shape
    block_r = min(block_r, r)
    assert r % block_r == 0, (r, block_r)
    sel = (jnp.arange(c, dtype=jnp.int32)[:, None] == idx[None, :]).astype(jnp.float32)
    opv = (jnp.arange(ref.NUM_OPCODES, dtype=jnp.int32) == op).astype(jnp.float32)
    grid = (r // block_r,)
    return pl.pallas_call(
        _gate_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, 4), lambda i: (0, 0)),
            pl.BlockSpec((ref.NUM_OPCODES,), lambda i: (0,)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(sel, opv, state, err)
