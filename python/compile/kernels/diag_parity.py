"""Layer-1 Pallas kernel: wrap-around diagonal parity extraction.

This is the ECC check-bit computation of the paper's Fig. 2(b,c): for each
m x m block, one parity bit per *leading* diagonal and one per *counter*
diagonal. On hardware the diagonal alignment is produced by a barrel
shifter between the crossbar and the check-bit extension; here the same
shift pattern is a per-row lane `roll` — row i is rotated by -i (leading)
or +i (counter) so that diagonals line up as columns, and the parity
reduces over rows as sum mod 2.

Tiled one block-batch entry per grid step; VMEM holds one (m, m) tile plus
two rotated copies — negligible footprint, VPU-bound.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _diag_parity_kernel(blk_ref, out_ref):
    blk = blk_ref[0]  # (m, m)
    m = blk.shape[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    # Leading diagonal d = (j - i) mod m : rotate row i left by i.
    lead_src = (j + i) % m
    # Counter diagonal d = (j + i) mod m : rotate row i right by i.
    cnt_src = (j - i) % m
    lead_aligned = jnp.take_along_axis(blk, lead_src, axis=1)
    cnt_aligned = jnp.take_along_axis(blk, cnt_src, axis=1)
    lead = jnp.mod(jnp.sum(lead_aligned, axis=0), 2.0)
    cnt = jnp.mod(jnp.sum(cnt_aligned, axis=0), 2.0)
    out_ref[0] = jnp.concatenate([lead, cnt])


@jax.jit
def diag_parity(blocks):
    """(B, m, m) {0,1} blocks -> (B, 2m) diagonal parities.

    Matches `ref.diag_parity_ref` bit-exactly.
    """
    bsz, m, _ = blocks.shape
    return pl.pallas_call(
        _diag_parity_kernel,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, m, m), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, 2 * m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, 2 * m), jnp.float32),
        interpret=True,
    )(blocks)
