"""Layer-1 Pallas kernel: per-bit TMR voting (paper Section V).

Majority-of-three is realized the way the mMPU does it: a FELIX Minority3
gate followed by a MAGIC NOT, each itself subject to direct soft errors
(the `err_min` / `err_not` flip masks). Voting is *per-bit*, which the
paper shows strictly dominates per-element voting.

Pure VPU elementwise kernel; tiled over rows with BlockSpec. VMEM holds
six (BLOCK_R, C) tiles -> footprint 6 * BLOCK_R * C * 4 B (0.75 MiB at
128 x 256), trivially within budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 128


def _vote3_kernel(a_ref, b_ref, c_ref, em_ref, en_ref, out_ref):
    a, b, c = a_ref[...], b_ref[...], c_ref[...]
    em, en = em_ref[...], en_ref[...]
    maj = a * b + a * c + b * c - 2.0 * a * b * c
    minority = 1.0 - maj
    minority = minority + em - 2.0 * minority * em  # faulty Minority3 output
    out = 1.0 - minority
    out_ref[...] = out + en - 2.0 * out * en  # faulty NOT output


@functools.partial(jax.jit, static_argnames=("block_r",))
def vote3(a, b, c, err_min, err_not, *, block_r=DEFAULT_BLOCK_R):
    """Per-bit majority vote of three (R, C) {0,1} planes with faulty gates.

    Matches `ref.vote3_ref` bit-exactly.
    """
    r, cc = a.shape
    block_r = min(block_r, r)
    assert r % block_r == 0, (r, block_r)
    spec = pl.BlockSpec((block_r, cc), lambda i: (i, 0))
    return pl.pallas_call(
        _vote3_kernel,
        grid=(r // block_r,),
        in_specs=[spec] * 5,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, cc), jnp.float32),
        interpret=True,
    )(a, b, c, err_min, err_not)
