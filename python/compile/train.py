"""Build-time training of MicroNet, the end-to-end case-study model.

The paper's case study uses AlexNet/ImageNet on FloatPIM, which it treats
*analytically* (constants M, W, p_mask). To validate the error-propagation
mechanism on a network the crossbar simulator can actually execute
end-to-end, we train a small MLP ("MicroNet", 64 -> H -> 10) on a
synthetic 8x8 digit-prototype dataset. Training happens HERE, once, at
`make artifacts` time; rust only ever loads the exported weights.

Exports (consumed by `rust/src/nn/micronet.rs`):
  weights.bin  f32 LE: w1 (64*H row-major), b1 (H), w2 (H*10), b2 (10)
  evalset.bin  f32 LE: N_EVAL * 64 pixels, then N_EVAL labels
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import model

IN_DIM = 64  # 8x8
HIDDEN = 32
N_CLASSES = 10
N_TRAIN = 2048
N_EVAL = 512
FLIP_P = 0.08  # per-pixel noise on the prototypes
SEED = 0x5EED
STEPS = 400
LR = 0.5


def make_dataset(rng, n):
    """n noisy samples of 10 random-but-fixed 8x8 binary prototypes."""
    protos = (rng.random((N_CLASSES, IN_DIM)) < 0.5).astype(np.float32)
    labels = rng.integers(0, N_CLASSES, size=n)
    x = protos[labels].copy()
    flips = rng.random((n, IN_DIM)) < FLIP_P
    x[flips] = 1.0 - x[flips]
    return x.astype(np.float32), labels.astype(np.int64)


def loss_fn(params, x, y):
    w1, b1, w2, b2 = params
    logits = model.micronet_fwd_clean_ref(x, w1, b1, w2, b2)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


def accuracy(params, x, y):
    w1, b1, w2, b2 = params
    logits = model.micronet_fwd_clean_ref(x, w1, b1, w2, b2)
    return float(jnp.mean(jnp.argmax(logits, axis=1) == y))


def train(verbose=False):
    rng = np.random.default_rng(SEED)
    xtr, ytr = make_dataset(rng, N_TRAIN)
    xev, yev = make_dataset(np.random.default_rng(SEED), N_EVAL)  # same protos

    key = jax.random.PRNGKey(SEED)
    k1, k2 = jax.random.split(key)
    params = [
        jax.random.normal(k1, (IN_DIM, HIDDEN)) * 0.1,
        jnp.zeros((HIDDEN,)),
        jax.random.normal(k2, (HIDDEN, N_CLASSES)) * 0.1,
        jnp.zeros((N_CLASSES,)),
    ]
    grad = jax.jit(jax.grad(loss_fn))
    for step in range(STEPS):
        g = grad(params, xtr, ytr)
        params = [p - LR * gi for p, gi in zip(params, g)]
        if verbose and step % 100 == 0:
            print(f"step {step}: loss={loss_fn(params, xtr, ytr):.4f}")
    acc = accuracy(params, xev, yev)
    if verbose:
        print(f"eval accuracy: {acc:.4f}")
    return [np.asarray(p, dtype=np.float32) for p in params], (xev, yev), acc


def export(outdir):
    params, (xev, yev), acc = train(verbose=True)
    w1, b1, w2, b2 = params
    with open(f"{outdir}/weights.bin", "wb") as f:
        for arr in (w1, b1, w2, b2):
            f.write(np.ascontiguousarray(arr, dtype="<f4").tobytes())
    with open(f"{outdir}/evalset.bin", "wb") as f:
        f.write(np.ascontiguousarray(xev, dtype="<f4").tobytes())
        f.write(np.ascontiguousarray(yev.astype(np.float32), dtype="<f4").tobytes())
    return acc
