"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is listed in `manifest.txt` (simple `key=value` lines)
which `rust/src/runtime/artifacts.rs` parses. Shapes are static: one
artifact per configuration.

Usage: (cd python && python -m compile.aot --out ../artifacts)
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, train

F32 = jnp.float32
I32 = jnp.int32

# (rows, cols, steps) configurations for the crossbar program executor.
# s=256 covers N-bit adders; s=4096 covers 32-bit MultPIM (~3.5k gates).
GATE_SCAN_CFGS = [(64, 64, 64), (128, 128, 256), (128, 128, 4096)]
VOTE_CFGS = [(64, 64), (128, 128)]
DIAG_CFGS = [(64, 16)]  # (blocks, m)
MICRONET_BATCHES = [64, 512]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(outdir, name, fn, specs, manifest, **meta):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)
    kv = " ".join(f"{k}={v}" for k, v in meta.items())
    manifest.append(f"artifact name={name} file={fname} {kv}".strip())
    print(f"  {fname}: {len(text)} chars")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true", help="HLO only (tests)")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    manifest = []

    print("[aot] lowering gate_scan executors")
    for r, c, s in GATE_SCAN_CFGS:
        emit(
            outdir,
            f"gate_scan_r{r}_c{c}_s{s}",
            model.gate_scan,
            (
                jax.ShapeDtypeStruct((r, c), F32),
                jax.ShapeDtypeStruct((s,), I32),
                jax.ShapeDtypeStruct((s, 4), I32),
                jax.ShapeDtypeStruct((s, r), F32),
            ),
            manifest,
            kind="gate_scan",
            r=r,
            c=c,
            s=s,
        )

    print("[aot] lowering vote3 kernels")
    for r, c in VOTE_CFGS:
        spec = jax.ShapeDtypeStruct((r, c), F32)
        emit(
            outdir,
            f"vote3_r{r}_c{c}",
            model.vote3,
            (spec,) * 5,
            manifest,
            kind="vote3",
            r=r,
            c=c,
        )

    print("[aot] lowering diag_parity kernels")
    for b, m in DIAG_CFGS:
        emit(
            outdir,
            f"diag_parity_b{b}_m{m}",
            model.diag_parity,
            (jax.ShapeDtypeStruct((b, m, m), F32),),
            manifest,
            kind="diag_parity",
            b=b,
            m=m,
        )

    h = train.HIDDEN
    print("[aot] lowering micronet forward")
    for b in MICRONET_BATCHES:
        emit(
            outdir,
            f"micronet_fwd_b{b}",
            model.micronet_fwd,
            (
                jax.ShapeDtypeStruct((b, train.IN_DIM), F32),
                jax.ShapeDtypeStruct((train.IN_DIM, h), F32),
                jax.ShapeDtypeStruct((h,), F32),
                jax.ShapeDtypeStruct((h, train.N_CLASSES), F32),
                jax.ShapeDtypeStruct((train.N_CLASSES,), F32),
                jax.ShapeDtypeStruct((train.IN_DIM, h), F32),
                jax.ShapeDtypeStruct((train.IN_DIM, h), F32),
                jax.ShapeDtypeStruct((h, train.N_CLASSES), F32),
                jax.ShapeDtypeStruct((h, train.N_CLASSES), F32),
            ),
            manifest,
            kind="micronet",
            b=b,
            h=h,
            indim=train.IN_DIM,
            classes=train.N_CLASSES,
        )

    if not args.skip_train:
        print("[aot] training MicroNet (build-time only)")
        acc = train.export(outdir)
        manifest.append(
            f"weights file=weights.bin h={h} indim={train.IN_DIM} "
            f"classes={train.N_CLASSES} train_acc={acc:.4f}"
        )
        manifest.append(f"evalset file=evalset.bin n={train.N_EVAL} indim={train.IN_DIM}")

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] wrote {len(manifest)} manifest entries to {outdir}/manifest.txt")


if __name__ == "__main__":
    main()
