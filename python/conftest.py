import os
import sys

# Make the build-time `compile` package importable regardless of how
# pytest is invoked (it lives next to this conftest).
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
